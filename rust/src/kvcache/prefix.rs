//! Cross-session **prefix sharing** with copy-on-write (the ROADMAP's
//! "Prefix sharing across sessions" item).
//!
//! Identical prompt prefixes (system prompts, few-shot templates) used
//! to be quantized and charged to the [`BlockPool`](super::BlockPool)
//! once **per session**, so the prefix bytes — not the per-request
//! deltas — capped the max concurrent batch for common-system-prompt
//! workloads. This module makes prefill blocks shareable:
//!
//! * [`PrefixIndex`] — a hash-trie over prompt token prefixes at block
//!   granularity, owned by the scheduler. The first session to prefill
//!   a prompt *publishes* its block-aligned prefix payload (quantized
//!   codes/scales/tags for the CT cache, f32 rows for the baseline
//!   cache); the pool is charged **once** for the resident payload.
//! * [`SharedPrefix`] — one resident, refcounted, read-only payload.
//!   Reclaim ([`PrefixIndex::reclaim_unreferenced`]) only ever removes
//!   entries with zero attached sessions — eviction and preemption can
//!   never take a block another session still references.
//! * [`AttachedPrefix`] — one session's handle on a shared prefix. The
//!   session's cache attaches the payload instead of re-quantizing it,
//!   its byte accounting covers only the *delta* (divergent prompt tail
//!   + generation headroom), and the first write past the shared
//!   boundary triggers **copy-on-write**
//!   ([`AttachedPrefix::try_privatize`]): the session reserves the
//!   prefix bytes for itself, drops its shared reference, and from then
//!   on owns (and pays for) a private copy. A CoW that cannot reserve
//!   pool bytes is denied — the shared region stays read-only and the
//!   eviction policy works around it — so sharing can never over-commit
//!   the pool.
//!
//! Lifecycle: trie match → ref bump → attach (delta-only accounting) →
//! CoW on first divergent write → ref drop on completion/privatize →
//! reclaim when unreferenced and the pool needs bytes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::quant::{packed_bits_per_elem, Precision};
use crate::syncx::{rank, RankedMutex};

use super::pool::ByteLease;
use super::BlockPool;

/// Geometry + precision key a payload is only valid for: sessions may
/// share a prefix only when their caches would have produced the exact
/// same bytes for it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixGeom {
    /// Cache family ("quant" / "fp32"), mirroring
    /// [`KvBackend::kind`](super::KvBackend::kind).
    pub kind: &'static str,
    pub layers: usize,
    pub hkv: usize,
    pub dh: usize,
    /// Prefill precision tag (quant family; unused sentinel for fp32).
    pub prec_tag: u8,
}

impl PrefixGeom {
    pub fn kv_dim(&self) -> usize {
        self.hkv * self.dh
    }

    /// Pool bytes `n` prefix tokens occupy under this geometry — the
    /// same packed accounting the backends charge, floored so a sharer
    /// never under-pays its delta.
    pub fn bytes_for(&self, n: usize) -> u64 {
        let elems = (n * self.layers * 2 * self.kv_dim()) as f64;
        if self.kind == "fp32" {
            (elems * 4.0) as u64
        } else {
            (elems * packed_bits_per_elem(Precision::from_tag(self.prec_tag)) / 8.0).floor() as u64
        }
    }
}

/// The shareable prefill payload, compacted `[L, full_len, ...]` — the
/// exact bytes a session's own `write_prefill` would have produced for
/// the same tokens.
pub enum PrefixPayload {
    /// Quantized CT prefill blocks (codes, group scales, precision tags).
    Quant {
        full_len: usize,
        k_codes: Vec<u8>,
        k_scales: Vec<f32>,
        v_codes: Vec<u8>,
        v_scales: Vec<f32>,
        tags: Vec<u8>,
    },
    /// Full-precision prefill rows (FullKV / eviction baselines).
    Fp32 { full_len: usize, k: Vec<f32>, v: Vec<f32> },
}

impl PrefixPayload {
    pub fn full_len(&self) -> usize {
        match self {
            PrefixPayload::Quant { full_len, .. } => *full_len,
            PrefixPayload::Fp32 { full_len, .. } => *full_len,
        }
    }
}

/// Process-wide id source for [`SharedPrefix::id`] — a deterministic
/// counter (not a timestamp) so ids are stable across runs with the
/// same publish order.
static NEXT_PREFIX_ID: AtomicU64 = AtomicU64::new(1);

/// One resident shared prefix: read-only payload + attached-session
/// refcount. Lives in the trie until reclaimed (refs == 0 only).
pub struct SharedPrefix {
    pub geom: PrefixGeom,
    pub full_len: usize,
    /// Pool bytes charged once for residency ([`PrefixGeom::bytes_for`]
    /// of `full_len`).
    pub bytes: u64,
    pub payload: PrefixPayload,
    /// Sessions currently attached (including suspended ones).
    refs: AtomicUsize,
    /// The ledgered pool charge backing this entry's residency. Taken
    /// out (and settled) by [`PrefixIndex::reclaim_unreferenced`]; if
    /// the entry instead dies with the index (trie teardown), `Drop`
    /// settles it quietly — the documented transfer rule for residency.
    residency: RankedMutex<Option<ByteLease>>,
    /// Process-unique identity, used by the fused-decode engine to
    /// dedupe batch members aliasing the same physical prefix copy.
    id: u64,
    /// Logical-clock stamp of the most recent attach/publish touching
    /// this entry ([`PrefixIndex`]'s clock) — the LRU key for
    /// [`PrefixIndex::reclaim_unreferenced`].
    last_touch: AtomicU64,
}

impl SharedPrefix {
    pub fn refs(&self) -> usize {
        self.refs.load(Ordering::SeqCst)
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Logical time of the last attach/publish hit (LRU recency).
    pub fn last_touch(&self) -> u64 {
        self.last_touch.load(Ordering::SeqCst)
    }
}

impl Drop for SharedPrefix {
    fn drop(&mut self) {
        // index teardown: the entry leaves the trie without passing
        // through reclaim, so its residency charge settles here — the
        // one place a residency lease may end other than reclaim
        if let Some(lease) = self.residency.lock().take() {
            lease.settle();
        }
    }
}

impl std::fmt::Debug for SharedPrefix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedPrefix")
            .field("id", &self.id)
            .field("geom", &self.geom)
            .field("full_len", &self.full_len)
            .field("bytes", &self.bytes)
            .field("refs", &self.refs())
            .field("last_touch", &self.last_touch())
            .finish_non_exhaustive()
    }
}

/// A session's handle on a [`SharedPrefix`]: holds one reference, knows
/// how many tokens this session attached, and carries the
/// copy-on-write state.
#[must_use = "an AttachedPrefix holds a shared-prefix reference: store it or the ref drops"]
pub struct AttachedPrefix {
    shared: Arc<SharedPrefix>,
    index: Arc<PrefixIndex>,
    /// Tokens of the shared payload this session attached (its common
    /// prefix with the published tokens, block-aligned, `<= full_len`).
    attach_len: usize,
    /// Delta the session's accounting subtracts while the attachment is
    /// active ([`PrefixGeom::bytes_for`] of `attach_len`).
    bytes: u64,
    privatized: AtomicBool,
    /// The ledgered pool charge created by
    /// [`AttachedPrefix::try_privatize`], not yet folded into the
    /// owning session's lease (drained by `Session::sync_pool` /
    /// `release_pool` via [`AttachedPrefix::take_cow_lease`]). Ranked
    /// above every scheduler lock: the drain runs on `fail`/`finish`
    /// paths that hold the scheduler's inner lock.
    cow: RankedMutex<Option<ByteLease>>,
    /// Guards the single refcount drop (privatize vs handle drop).
    detached: AtomicBool,
    /// The pool CoW privatization charges: the **owning session's**
    /// pool. With a per-scheduler index this is the index's own pool;
    /// with a fleet-global index it is the session's replica pool —
    /// charging `index.pool` there would leak the private copy's bytes
    /// into the fleet pool while the session's own accounting released
    /// them to its replica pool.
    charge: Arc<BlockPool>,
}

impl AttachedPrefix {
    pub fn attach_len(&self) -> usize {
        self.attach_len
    }

    /// Pool bytes the attachment saves while active.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn payload(&self) -> &PrefixPayload {
        &self.shared.payload
    }

    /// The underlying shared entry — the fused-decode engine keys batch
    /// members' block tables on [`SharedPrefix::id`] so sessions
    /// aliasing the same entry share one physical arena copy.
    pub fn shared_arc(&self) -> Arc<SharedPrefix> {
        Arc::clone(&self.shared)
    }

    pub fn geom(&self) -> PrefixGeom {
        self.shared.geom
    }

    /// True while the session still reads the shared (read-only) blocks.
    pub fn is_active(&self) -> bool {
        !self.privatized.load(Ordering::SeqCst)
    }

    /// Copy-on-write: the session is about to write into the shared
    /// region, so it must own the prefix bytes privately. Reserves the
    /// attachment's bytes in the pool, drops the shared reference, and
    /// marks the attachment privatized. Returns false (leaving the
    /// region read-only) when the pool cannot cover the now-private
    /// copy — the caller must leave the shared blocks untouched.
    #[must_use = "a denied CoW means the shared region must stay read-only"]
    pub fn try_privatize(&self) -> bool {
        if self.privatized.load(Ordering::SeqCst) {
            return true;
        }
        let Some(lease) = self.charge.lease(self.bytes) else {
            self.index.cow_denied.fetch_add(1, Ordering::SeqCst);
            return false;
        };
        self.privatized.store(true, Ordering::SeqCst);
        *self.cow.lock() = Some(lease);
        self.release_ref();
        self.index.cow_faults.fetch_add(1, Ordering::SeqCst);
        true
    }

    /// A fresh handle on the same shared entry whose CoW bytes charge
    /// `pool` instead of this handle's pool — sessions on replica pools
    /// (and migrating sessions changing replicas) re-anchor their
    /// attachment with this. Preserves privatization/CoW state; an
    /// active handle bumps the shared refcount for the new handle (the
    /// old one releases its reference when dropped, so the entry's
    /// count never dips — reclaim can never race the swap). Returns the
    /// same handle when the charge pool already matches.
    pub fn rebind_charge(self: &Arc<Self>, pool: Arc<BlockPool>) -> Arc<AttachedPrefix> {
        if Arc::ptr_eq(&self.charge, &pool) {
            return Arc::clone(self);
        }
        let active = self.is_active();
        if active {
            // bump-before-release: the old handle still holds its ref,
            // so the count stays >= 1 throughout and reclaim (which only
            // touches zero-ref entries, under the trie lock) is safe
            self.shared.refs.fetch_add(1, Ordering::SeqCst);
        }
        Arc::new(AttachedPrefix {
            shared: Arc::clone(&self.shared),
            index: Arc::clone(&self.index),
            attach_len: self.attach_len,
            bytes: self.bytes,
            privatized: AtomicBool::new(!active),
            cow: {
                debug_assert!(
                    self.cow.lock().is_none(),
                    "rebind with an undrained CoW lease crosses pools"
                );
                RankedMutex::new(&rank::PREFIX_COW, None)
            },
            detached: AtomicBool::new(!active),
            charge: pool,
        })
    }

    /// Count this attach as served by **aliasing** the resident payload
    /// (zero-memcpy) in the owning index's stats — called by the backend
    /// once its block tables point at the shared copy.
    pub fn note_alias(&self) {
        self.index.note_alias(self.bytes);
    }

    /// Drain the pool lease created by a privatization so the owning
    /// session can fold it into its own lease. `None` once drained (or
    /// if no privatization happened).
    #[must_use = "the drained CoW lease must be merged into the session's lease"]
    pub fn take_cow_lease(&self) -> Option<ByteLease> {
        self.cow.lock().take()
    }

    fn release_ref(&self) {
        if !self.detached.swap(true, Ordering::SeqCst) {
            self.shared.refs.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Drop for AttachedPrefix {
    fn drop(&mut self) {
        self.release_ref();
    }
}

/// Point-in-time counters of a [`PrefixIndex`] (surfaced through
/// [`SchedSnapshot`](crate::metrics::SchedSnapshot) and the server
/// `stats` reply).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Lookups that matched a resident prefix (a session attached).
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Prefixes published (residency charged to the pool).
    pub inserts: u64,
    /// Publishes refused because the pool had no room for residency.
    pub publish_fails: u64,
    /// Copy-on-write privatizations (first write past a shared boundary).
    pub cow_faults: u64,
    /// CoW attempts denied because the pool could not cover the private
    /// copy (the shared region stayed read-only).
    pub cow_denied: u64,
    /// Unreferenced entries reclaimed under memory pressure.
    pub reclaims: u64,
    pub reclaimed_bytes: u64,
    /// Attaches served by **aliasing** the resident payload (block
    /// tables pointed at the shared physical copy, zero memcpy) instead
    /// of copying it into the session's cache.
    pub alias_hits: u64,
    /// Payload bytes those aliased attaches did *not* copy.
    pub alias_bytes: u64,
    /// Gauge: bytes currently resident in the pool for shared prefixes.
    pub resident_bytes: u64,
    /// Gauge: resident shared-prefix entries.
    pub resident_entries: u64,
}

#[derive(Default)]
struct TrieNode {
    /// One child per distinct next *block* of tokens.
    children: HashMap<Vec<i32>, TrieNode>,
    /// Entries whose first `depth` blocks equal the path to this node.
    entries: Vec<Arc<SharedPrefix>>,
}

impl TrieNode {
    fn retain_not(&mut self, victims: &[*const SharedPrefix]) {
        self.entries.retain(|e| !victims.contains(&Arc::as_ptr(e)));
        for child in self.children.values_mut() {
            child.retain_not(victims);
        }
        self.children
            .retain(|_, c| !c.entries.is_empty() || !c.children.is_empty());
    }
}

/// The scheduler-owned prefix index: hash-trie over prompt token
/// prefixes at block granularity, plus the pool-residency accounting
/// for every published payload.
pub struct PrefixIndex {
    pool: Arc<BlockPool>,
    /// Trie granularity — prefixes match in whole blocks, mirroring the
    /// CT block table's physical block size.
    block_size: usize,
    /// Ranked above the scheduler's inner lock: `try_admit` reclaims
    /// with that lock held.
    root: RankedMutex<TrieNode>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    publish_fails: AtomicU64,
    cow_faults: AtomicU64,
    cow_denied: AtomicU64,
    reclaims: AtomicU64,
    reclaimed_bytes: AtomicU64,
    resident_bytes: AtomicU64,
    resident_entries: AtomicU64,
    alias_hits: AtomicU64,
    alias_bytes: AtomicU64,
    /// Monotonic logical clock stamped into [`SharedPrefix::last_touch`]
    /// on every attach/publish — recency for LRU reclaim.
    clock: AtomicU64,
}

impl PrefixIndex {
    pub fn new(pool: Arc<BlockPool>, block_size: usize) -> Arc<PrefixIndex> {
        assert!(block_size > 0);
        Arc::new(PrefixIndex {
            pool,
            block_size,
            root: RankedMutex::new(&rank::PREFIX_ROOT, TrieNode::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            publish_fails: AtomicU64::new(0),
            cow_faults: AtomicU64::new(0),
            cow_denied: AtomicU64::new(0),
            reclaims: AtomicU64::new(0),
            reclaimed_bytes: AtomicU64::new(0),
            resident_bytes: AtomicU64::new(0),
            resident_entries: AtomicU64::new(0),
            alias_hits: AtomicU64::new(0),
            alias_bytes: AtomicU64::new(0),
            clock: AtomicU64::new(0),
        })
    }

    fn touch(&self, shared: &SharedPrefix) {
        let now = self.clock.fetch_add(1, Ordering::SeqCst) + 1;
        shared.last_touch.store(now, Ordering::SeqCst);
    }

    /// Record an attach served by aliasing the resident payload (the
    /// backend pointed block tables at the shared copy instead of
    /// memcpying `bytes` into the session's cache).
    pub fn note_alias(&self, bytes: u64) {
        self.alias_hits.fetch_add(1, Ordering::SeqCst);
        self.alias_bytes.fetch_add(bytes, Ordering::SeqCst);
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Longest block-aligned prefix of `prompt` (capped at the compiled
    /// prefill length) that can ever be shared.
    pub fn shareable_len(&self, prompt_len: usize, prefill_len: usize) -> usize {
        (prompt_len.min(prefill_len) / self.block_size) * self.block_size
    }

    /// Match the longest resident block-aligned prefix of `prompt` with
    /// compatible geometry and attach to it (ref bump). Counts a hit or
    /// a miss.
    pub fn attach(
        self: &Arc<Self>,
        prompt: &[i32],
        geom: PrefixGeom,
        prefill_len: usize,
    ) -> Option<Arc<AttachedPrefix>> {
        let att = self.attach_inner(prompt, geom, prefill_len);
        if att.is_none() {
            self.misses.fetch_add(1, Ordering::SeqCst);
        }
        att
    }

    /// [`PrefixIndex::attach`] without counting a miss — the
    /// second-chance lookup at prefill time follows a construction-time
    /// lookup, and one request must not count two misses. (A successful
    /// attach still counts its hit.)
    pub fn attach_quiet(
        self: &Arc<Self>,
        prompt: &[i32],
        geom: PrefixGeom,
        prefill_len: usize,
    ) -> Option<Arc<AttachedPrefix>> {
        self.attach_inner(prompt, geom, prefill_len)
    }

    fn attach_inner(
        self: &Arc<Self>,
        prompt: &[i32],
        geom: PrefixGeom,
        prefill_len: usize,
    ) -> Option<Arc<AttachedPrefix>> {
        let limit = self.shareable_len(prompt.len(), prefill_len);
        if limit == 0 {
            return None;
        }
        let root = self.root.lock();
        let mut node = &*root;
        let mut best: Option<(Arc<SharedPrefix>, usize)> = None;
        let mut depth = 0;
        while (depth + 1) * self.block_size <= limit {
            let block = &prompt[depth * self.block_size..(depth + 1) * self.block_size];
            let Some(child) = node.children.get(block) else {
                break;
            };
            node = child;
            depth += 1;
            if let Some(e) = node.entries.iter().find(|e| e.geom == geom) {
                best = Some((Arc::clone(e), depth * self.block_size));
            }
        }
        let (shared, attach_len) = best?;
        // ref bump under the trie lock so reclaim can never race it
        shared.refs.fetch_add(1, Ordering::SeqCst);
        drop(root);
        self.touch(&shared);
        self.hits.fetch_add(1, Ordering::SeqCst);
        Some(Arc::new(AttachedPrefix {
            bytes: geom.bytes_for(attach_len),
            shared,
            index: Arc::clone(self),
            attach_len,
            privatized: AtomicBool::new(false),
            cow: RankedMutex::new(&rank::PREFIX_COW, None),
            detached: AtomicBool::new(false),
            charge: Arc::clone(&self.pool),
        }))
    }

    /// Publish `tokens` (block-aligned, already prefilled by the caller)
    /// as a resident shared prefix: charge the pool for residency,
    /// register the entry at every block depth, and attach the
    /// publisher. Returns None when the pool has no room (counted) or
    /// the tokens are not shareable; if an equal-geometry entry covering
    /// these tokens already exists the publisher simply attaches to it.
    pub fn publish(
        self: &Arc<Self>,
        tokens: &[i32],
        geom: PrefixGeom,
        payload: PrefixPayload,
    ) -> Option<Arc<AttachedPrefix>> {
        let n = tokens.len();
        if n == 0 || n % self.block_size != 0 || payload.full_len() != n {
            return None;
        }
        let mut root = self.root.lock();
        // dedupe: someone published these tokens (or a longer prefix of
        // the same stream) between our miss and now
        {
            let mut node = &*root;
            let mut covered = None;
            for d in 0..n / self.block_size {
                let block = &tokens[d * self.block_size..(d + 1) * self.block_size];
                match node.children.get(block) {
                    Some(c) => node = c,
                    None => break,
                }
                if let Some(e) = node.entries.iter().find(|e| e.geom == geom) {
                    if (d + 1) * self.block_size == n {
                        covered = Some(Arc::clone(e));
                    }
                }
            }
            if let Some(shared) = covered {
                shared.refs.fetch_add(1, Ordering::SeqCst);
                drop(root);
                self.touch(&shared);
                return Some(Arc::new(AttachedPrefix {
                    bytes: geom.bytes_for(n),
                    shared,
                    index: Arc::clone(self),
                    attach_len: n,
                    privatized: AtomicBool::new(false),
                    cow: RankedMutex::new(&rank::PREFIX_COW, None),
                    detached: AtomicBool::new(false),
                    charge: Arc::clone(&self.pool),
                }));
            }
        }
        let bytes = geom.bytes_for(n);
        let Some(residency) = self.pool.lease(bytes) else {
            self.publish_fails.fetch_add(1, Ordering::SeqCst);
            return None;
        };
        let shared = Arc::new(SharedPrefix {
            geom,
            full_len: n,
            bytes,
            payload,
            refs: AtomicUsize::new(1), // the publisher attaches
            id: NEXT_PREFIX_ID.fetch_add(1, Ordering::SeqCst),
            last_touch: AtomicU64::new(0),
            residency: RankedMutex::new(&rank::PREFIX_RESIDENCY, Some(residency)),
        });
        self.touch(&shared);
        let mut node = &mut *root;
        for d in 0..n / self.block_size {
            let block = tokens[d * self.block_size..(d + 1) * self.block_size].to_vec();
            node = node.children.entry(block).or_default();
            node.entries.push(Arc::clone(&shared));
        }
        drop(root);
        self.inserts.fetch_add(1, Ordering::SeqCst);
        self.resident_bytes.fetch_add(bytes, Ordering::SeqCst);
        self.resident_entries.fetch_add(1, Ordering::SeqCst);
        Some(Arc::new(AttachedPrefix {
            bytes,
            shared,
            index: Arc::clone(self),
            attach_len: n,
            privatized: AtomicBool::new(false),
            cow: RankedMutex::new(&rank::PREFIX_COW, None),
            detached: AtomicBool::new(false),
            charge: Arc::clone(&self.pool),
        }))
    }

    /// Reclaim resident prefixes with **zero** attached sessions, in
    /// **LRU order** (coldest [`SharedPrefix::last_touch`] first), until
    /// at least `need` bytes came back (or nothing unreferenced is
    /// left). Entries still referenced by any session — running or
    /// suspended — are never touched. Returns the bytes released.
    pub fn reclaim_unreferenced(&self, need: u64) -> u64 {
        if need == 0 {
            return 0;
        }
        let mut root = self.root.lock();
        let mut candidates: Vec<Arc<SharedPrefix>> = Vec::new();
        collect_unreferenced(&root, &mut candidates);
        if candidates.is_empty() {
            return 0;
        }
        // coldest first: the entry no session has touched for the
        // longest logical time is the least likely to be re-attached
        candidates.sort_by_key(|e| e.last_touch());
        let mut victims: Vec<Arc<SharedPrefix>> = Vec::new();
        let mut freed = 0u64;
        for e in candidates {
            if freed >= need {
                break;
            }
            freed += e.bytes;
            victims.push(e);
        }
        let ptrs: Vec<*const SharedPrefix> = victims.iter().map(Arc::as_ptr).collect();
        root.retain_not(&ptrs);
        drop(root);
        let mut released = 0u64;
        for v in &victims {
            // settle the residency lease (the ledgered charge created at
            // publish); residency ranks above root, but taking it after
            // the trie unlock keeps the critical section minimal
            match v.residency.lock().take() {
                Some(lease) => {
                    debug_assert_eq!(lease.bytes(), v.bytes, "residency lease drifted");
                    lease.settle();
                }
                None => debug_assert!(false, "reclaimed entry had no residency lease"),
            }
            released += v.bytes;
            self.resident_bytes.fetch_sub(v.bytes, Ordering::SeqCst);
            self.resident_entries.fetch_sub(1, Ordering::SeqCst);
            self.reclaims.fetch_add(1, Ordering::SeqCst);
        }
        self.reclaimed_bytes.fetch_add(released, Ordering::SeqCst);
        released
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits.load(Ordering::SeqCst),
            misses: self.misses.load(Ordering::SeqCst),
            inserts: self.inserts.load(Ordering::SeqCst),
            publish_fails: self.publish_fails.load(Ordering::SeqCst),
            cow_faults: self.cow_faults.load(Ordering::SeqCst),
            cow_denied: self.cow_denied.load(Ordering::SeqCst),
            reclaims: self.reclaims.load(Ordering::SeqCst),
            reclaimed_bytes: self.reclaimed_bytes.load(Ordering::SeqCst),
            alias_hits: self.alias_hits.load(Ordering::SeqCst),
            alias_bytes: self.alias_bytes.load(Ordering::SeqCst),
            resident_bytes: self.resident_bytes.load(Ordering::SeqCst),
            resident_entries: self.resident_entries.load(Ordering::SeqCst),
        }
    }
}

/// Depth-first scan for **all** unreferenced entries, deduped by
/// pointer (each entry is registered at every block depth). The caller
/// orders them by recency — trie order is an arbitrary eviction policy.
fn collect_unreferenced(node: &TrieNode, out: &mut Vec<Arc<SharedPrefix>>) {
    for e in &node.entries {
        if e.refs() == 0 && !out.iter().any(|v| Arc::ptr_eq(v, e)) {
            out.push(Arc::clone(e));
        }
    }
    for child in node.children.values() {
        collect_unreferenced(child, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> PrefixGeom {
        PrefixGeom { kind: "fp32", layers: 2, hkv: 1, dh: 16, prec_tag: 0 }
    }

    fn payload(n: usize, g: &PrefixGeom) -> PrefixPayload {
        PrefixPayload::Fp32 {
            full_len: n,
            k: vec![0.5; g.layers * n * g.kv_dim()],
            v: vec![-0.5; g.layers * n * g.kv_dim()],
        }
    }

    #[test]
    fn publish_then_attach_longest_match() {
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let g = geom();
        let tokens: Vec<i32> = (0..16).collect();
        let pub_att = idx.publish(&tokens, g, payload(16, &g)).expect("publish fits");
        assert_eq!(pub_att.attach_len(), 16);
        assert_eq!(pool.used(), g.bytes_for(16), "residency charged once");

        // full match
        let prompt: Vec<i32> = (0..24).collect();
        let att = idx.attach(&prompt, g, 32).expect("hit");
        assert_eq!(att.attach_len(), 16);
        // partial (one-block) match: same first block, divergent second
        let mut fork = tokens.clone();
        fork[12] = 999;
        let att2 = idx.attach(&fork, g, 32).expect("hit at block 1");
        assert_eq!(att2.attach_len(), 8);
        // geometry mismatch never matches
        let other = PrefixGeom { layers: 4, ..g };
        assert!(idx.attach(&prompt, other, 32).is_none());
        let s = idx.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.inserts, 1);
        assert_eq!(s.resident_entries, 1);
        assert_eq!(s.resident_bytes, g.bytes_for(16));
    }

    #[test]
    fn refcounts_gate_reclaim() {
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let g = geom();
        let tokens: Vec<i32> = (0..8).collect();
        let a = idx.publish(&tokens, g, payload(8, &g)).expect("publish");
        let b = idx.attach(&tokens, g, 32).expect("hit");
        // two refs: nothing reclaimable
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 0);
        drop(a);
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 0, "one ref left");
        drop(b);
        let freed = idx.reclaim_unreferenced(u64::MAX);
        assert_eq!(freed, g.bytes_for(8));
        assert_eq!(pool.used(), 0, "residency returned");
        assert_eq!(idx.stats().resident_entries, 0);
        // reclaimed entries no longer match
        assert!(idx.attach(&tokens, g, 32).is_none());
    }

    #[test]
    fn privatize_reserves_pool_and_drops_ref() {
        let g = geom();
        let pool = Arc::new(BlockPool::new(3 * g.bytes_for(8)));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let tokens: Vec<i32> = (0..8).collect();
        let a = idx.publish(&tokens, g, payload(8, &g)).expect("publish");
        let b = idx.attach(&tokens, g, 32).expect("hit");
        assert!(a.is_active() && b.is_active());
        assert!(a.try_privatize(), "pool has room");
        assert!(!a.is_active());
        let cow = a.take_cow_lease().expect("privatize parked a lease");
        assert_eq!(cow.bytes(), g.bytes_for(8));
        assert!(a.take_cow_lease().is_none(), "drained once");
        assert_eq!(pool.used(), 2 * g.bytes_for(8), "residency + private copy");
        // exhaust the pool: b's CoW is denied and it stays shared
        assert!(pool.reserve(pool.free()));
        assert!(!b.try_privatize());
        assert!(b.is_active());
        let s = idx.stats();
        assert_eq!(s.cow_faults, 1);
        assert_eq!(s.cow_denied, 1);
        // b still holds the only ref; reclaim must not touch the entry
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 0);
        drop(b);
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), g.bytes_for(8));
        cow.settle();
    }

    #[test]
    fn publish_dedupes_and_respects_pool() {
        let g = geom();
        let pool = Arc::new(BlockPool::new(g.bytes_for(8)));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let tokens: Vec<i32> = (0..8).collect();
        let a = idx.publish(&tokens, g, payload(8, &g)).expect("first publish");
        // second publish of the same tokens attaches instead of double-charging
        let b = idx.publish(&tokens, g, payload(8, &g)).expect("dedup attach");
        assert_eq!(pool.used(), g.bytes_for(8));
        assert_eq!(idx.stats().inserts, 1);
        drop(a);
        drop(b);
        // pool full: a different publish is refused and counted
        let other: Vec<i32> = (100..108).collect();
        assert!(idx.publish(&other, g, payload(8, &g)).is_none());
        assert_eq!(idx.stats().publish_fails, 1);
        // unaligned / empty publishes are refused outright
        assert!(idx.publish(&tokens[..5], g, payload(5, &g)).is_none());
        assert!(idx.publish(&[], g, payload(0, &g)).is_none());
    }

    #[test]
    fn reclaim_is_lru_coldest_first() {
        let g = geom();
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let streams: Vec<Vec<i32>> = vec![
            (0..8).collect(),
            (100..108).collect(),
            (200..208).collect(),
        ];
        // publish a, b, c — all immediately unreferenced
        for s in &streams {
            drop(idx.publish(s, g, payload(8, &g)).expect("publish"));
        }
        // re-touch a (attach + drop): recency is now b < c < a
        drop(idx.attach(&streams[0], g, 32).expect("hit"));
        // distinct ids, monotonic publish order
        let a = idx.attach(&streams[0], g, 32).expect("a resident");
        let c = idx.attach(&streams[2], g, 32).expect("c resident");
        assert_ne!(a.shared_arc().id(), c.shared_arc().id());
        assert!(c.shared_arc().last_touch() > a.shared_arc().last_touch());
        drop(a);
        drop(c);
        // need one entry's bytes: the coldest zero-ref entry (b) goes
        // first, everything else stays resident (a and c got re-touched
        // by the assertions above, keeping b coldest)
        assert_eq!(idx.reclaim_unreferenced(1), g.bytes_for(8));
        assert!(idx.attach(&streams[1], g, 32).is_none(), "b reclaimed");
        assert!(idx.attach(&streams[0], g, 32).is_some(), "a survives");
        assert!(idx.attach(&streams[2], g, 32).is_some(), "c survives");
        // next reclaim takes the now-coldest survivor until need is met
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 2 * g.bytes_for(8));
        assert_eq!(idx.stats().resident_entries, 0);
    }

    #[test]
    fn alias_counters_accumulate() {
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(pool, 8);
        assert_eq!(idx.stats().alias_hits, 0);
        idx.note_alias(64);
        idx.note_alias(128);
        let s = idx.stats();
        assert_eq!(s.alias_hits, 2);
        assert_eq!(s.alias_bytes, 192);
    }

    /// Fleet-global index regression (ISSUE 9 bugfix): a session on a
    /// replica pool re-anchors its attachment with `rebind_charge`, and
    /// its CoW privatization then charges the **replica** pool — not the
    /// fleet pool the index accounts residency against. The rebind's
    /// bump-before-release keeps the shared refcount >= 1 throughout, so
    /// reclaim can never take the entry out from under the swap.
    #[test]
    fn rebind_charge_moves_cow_to_replica_pool() {
        let g = geom();
        let fleet = Arc::new(BlockPool::new(1 << 30));
        let replica = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&fleet), 8);
        let tokens: Vec<i32> = (0..8).collect();
        drop(idx.publish(&tokens, g, payload(8, &g)).expect("publish"));
        let residency = g.bytes_for(8);
        assert_eq!(fleet.used(), residency, "residency on the fleet pool");

        let att = idx.attach(&tokens, g, 32).expect("hit");
        // same-pool rebind is a no-op returning the same handle
        let same = att.rebind_charge(Arc::clone(&fleet));
        assert!(Arc::ptr_eq(&att, &same));
        drop(same);
        let moved = att.rebind_charge(Arc::clone(&replica));
        assert!(moved.is_active(), "rebind preserves the shared state");
        // both handles alive: refcount covers them, nothing reclaimable
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 0);
        drop(att);
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), 0, "new handle still holds a ref");

        assert!(moved.try_privatize(), "replica pool has room");
        let cow = moved.take_cow_lease().expect("privatize parked a lease");
        assert_eq!(cow.bytes(), residency);
        assert_eq!(replica.used(), residency, "CoW charged the replica pool");
        assert_eq!(fleet.used(), residency, "fleet pool holds residency only");
        assert_eq!(idx.stats().cow_faults, 1);

        // privatization dropped the last ref: residency reclaims from
        // the fleet pool, and the replica charge is untouched
        drop(moved);
        assert_eq!(idx.reclaim_unreferenced(u64::MAX), residency);
        assert_eq!(fleet.used(), 0);
        assert_eq!(replica.used(), residency);
        cow.settle();
        replica.assert_conserved();
    }

    /// Concurrency regression (ISSUE 9 bugfix): replica threads hammer
    /// attach -> rebind-to-own-pool -> (sometimes) privatize -> drop
    /// while a reclaimer loops over the index. No referenced entry may
    /// ever be reclaimed mid-use, and at quiescence every book balances:
    /// fleet pool == resident gauge, replica pools fully drained.
    #[test]
    fn concurrent_attach_reclaim_across_replica_pools() {
        let g = geom();
        let fleet = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(Arc::clone(&fleet), 8);
        let streams: Vec<Vec<i32>> = (0..4).map(|s| (s * 100..s * 100 + 8).collect()).collect();
        for s in &streams {
            drop(idx.publish(s, g, payload(8, &g)).expect("publish"));
        }
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            for t in 0..3usize {
                let idx = &idx;
                let streams = &streams;
                scope.spawn(move || {
                    let replica = Arc::new(BlockPool::new(1 << 30));
                    for i in 0..400usize {
                        let tokens = &streams[(t + i) % streams.len()];
                        // entries race the reclaimer, so a miss is legal;
                        // an attached handle must stay fully usable
                        let Some(att) = idx.attach(tokens, g, 32) else { continue };
                        let mine = att.rebind_charge(Arc::clone(&replica));
                        drop(att);
                        assert_eq!(mine.attach_len(), 8);
                        assert_eq!(mine.payload().full_len(), 8, "payload gone mid-use");
                        if i % 3 == 0 && mine.try_privatize() {
                            // drain the CoW lease the way Session does,
                            // then settle it so the books can balance
                            let cow = mine.take_cow_lease().expect("privatize parked a lease");
                            assert_eq!(cow.bytes(), g.bytes_for(8));
                            cow.settle();
                        }
                        drop(mine);
                    }
                    assert_eq!(replica.used(), 0, "replica pool drained");
                });
            }
            scope.spawn(|| {
                while !stop.load(Ordering::SeqCst) {
                    idx.reclaim_unreferenced(1);
                    std::thread::yield_now();
                }
            });
            // republisher keeps reclaimed streams resident so attachers
            // make progress for the whole run
            for _ in 0..200usize {
                for s in &streams {
                    if let Some(att) = idx.publish(s, g, payload(8, &g)) {
                        drop(att);
                    }
                }
                std::thread::yield_now();
            }
            stop.store(true, Ordering::SeqCst);
        });
        idx.reclaim_unreferenced(u64::MAX);
        let s = idx.stats();
        assert_eq!(s.resident_entries, 0, "everything unreferenced reclaims");
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(fleet.used(), 0, "fleet pool balanced after the storm");
        assert_eq!(s.cow_denied, 0, "replica pools never ran out");
    }
}
