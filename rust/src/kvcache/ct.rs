//! [`CtCache`]: the per-request quantized paged cache a decode session owns.
//!
//! Combines (per layer) a [`LayerTable`] with the engine-facing slabs
//! (`k_codes/k_scales/v_codes/v_scales/tags/mask`) plus the shared
//! full-precision ring buffer B_buf (§4.2).  The coordinator calls:
//!
//! * [`CtCache::write_prefill`] — quantize prompt K/V (treated as **R**
//!   thoughts per §6.1) straight into slots.
//! * [`CtCache::push_token`] — stash one decode token's K/V in B_buf; when
//!   the buffer reaches the group size it is flushed: each token is group
//!   quantized at its thought's precision (TBQ) and placed by CT.
//! * [`CtCache::soft_evict_slots`] — TBE soft eviction (mask goes 0, slot
//!   becomes reclaimable, payload left in place).
//!
//! The `mask` slab the kernel sees is exactly `filled ∧ ¬evicted`.

use std::sync::Arc;

use crate::quant::{dequant_groups, quant_groups, Precision, GROUP_SIZE};
use crate::runtime::{QuantCache, SharedQuantRows};

use super::block_table::{LayerTable, SlotId};
use super::prefix::{PrefixPayload, SharedPrefix};
use super::Thought;

/// Geometry of a request's cache (from the manifest + serving config).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    pub layers: usize,
    pub capacity: usize,
    pub block_size: usize,
    pub hkv: usize,
    pub dh: usize,
    pub buf_slots: usize,
}

impl CacheConfig {
    pub fn groups(&self) -> usize {
        self.dh / GROUP_SIZE
    }

    pub fn kv_dim(&self) -> usize {
        self.hkv * self.dh
    }
}

/// A thought segment (contiguous CoT span of one thought type, §3.1 fn.3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentInfo {
    pub id: usize,
    pub thought: Thought,
    pub start_pos: usize,
    pub end_pos: usize, // exclusive; grows while the segment is active
    /// Times this segment has been selected for eviction (annealing level n).
    pub evict_level: usize,
}

/// One buffered (not yet quantized) token.
#[derive(Debug, Clone)]
struct BufToken {
    pos: usize,
    segment: usize,
    thought: Thought,
}

/// One layer's compacted live payload inside a [`CtSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct CtLayerSnapshot {
    /// Live slot ids, ascending.
    pub slots: Vec<u32>,
    /// Per-live-slot precision tag.
    pub tags: Vec<u8>,
    /// `[n, Hkv*Dh]` packed K codes of the live slots.
    pub k_codes: Vec<u8>,
    /// `[n, Hkv*G]` K group scales of the live slots.
    pub k_scales: Vec<f32>,
    pub v_codes: Vec<u8>,
    pub v_scales: Vec<f32>,
}

/// Compact suspend-to-host image of a [`CtCache`]: only the *live*
/// payload is captured (soft-evicted slots keep stale bytes that the
/// mask-gated kernel never reads), plus the full CT metadata — block
/// tables with thought tags, segment masks and eviction masks — the
/// segment store, the B_buf full-precision residue, and the packed-bits
/// accounting. Restoring this image into a fresh cache of the same
/// geometry reproduces the decode stream bit-exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct CtSnapshot {
    pub cfg: CacheConfig,
    /// Per-layer CT block tables (thought / segment / eviction masks).
    pub tables: Vec<LayerTable>,
    pub segments: Vec<SegmentInfo>,
    /// Per-layer compacted live payload.
    pub layers: Vec<CtLayerSnapshot>,
    /// `(pos, segment, thought)` of each B_buf resident, in push order.
    pub buffered: Vec<(usize, usize, Thought)>,
    /// `[L, fill, Hkv*Dh]` compacted ring-buffer K payload.
    pub buf_k: Vec<f32>,
    pub buf_v: Vec<f32>,
    pub packed_bits_written: f64,
    pub tokens_written: u64,
}

impl CtSnapshot {
    /// Host bytes this snapshot occupies — payload vectors plus a
    /// conservative charge for the CT metadata. This is what the
    /// [`SwapPool`](super::SwapPool) accounts on swap-out.
    pub fn host_bytes(&self) -> u64 {
        let mut n = 0u64;
        for ls in &self.layers {
            n += ls.slots.len() as u64 * 4
                + ls.tags.len() as u64
                + (ls.k_codes.len() + ls.v_codes.len()) as u64
                + 4 * (ls.k_scales.len() + ls.v_scales.len()) as u64;
        }
        n += 4 * (self.buf_k.len() + self.buf_v.len()) as u64;
        n += self.buffered.len() as u64 * 24;
        for t in &self.tables {
            // block entries (start indices + segment mask + fixed fields)
            // and the two per-slot maps
            n += t.blocks.len() as u64 * (self.cfg.block_size as u64 * 8 + 64);
            n += t.capacity as u64 * 8;
        }
        n += self.segments.len() as u64 * 40;
        n
    }
}

/// The per-request Continuous-Thinking cache.
pub struct CtCache {
    pub cfg: CacheConfig,
    // engine-facing slabs, flattened [L, C, ...]
    pub k_codes: Vec<u8>,
    pub k_scales: Vec<f32>,
    pub v_codes: Vec<u8>,
    pub v_scales: Vec<f32>,
    pub tags: Vec<u8>,
    pub mask: Vec<f32>,
    pub buf_k: Vec<f32>,
    pub buf_v: Vec<f32>,
    pub buf_mask: Vec<f32>,
    // CT block tables, one per layer
    pub tables: Vec<LayerTable>,
    pub segments: Vec<SegmentInfo>,
    buffered: Vec<BufToken>,
    /// Cumulative packed bits written (memory-footprint accounting).
    pub packed_bits_written: f64,
    pub tokens_written: u64,
    /// Slots `0..shared_len` (every layer) hold a cross-session shared
    /// prefix and are **read-only**: eviction skips them until the
    /// owning backend privatizes the region (copy-on-write) and clears
    /// this marker. 0 = no shared region.
    shared_len: usize,
    /// When the shared region was attached by **aliasing**
    /// ([`CtCache::attach_prefix_alias`]): the resident entry whose
    /// payload physically holds the codes/scales for slots
    /// `0..shared_len`. The cache's own code/scale slabs are stale
    /// there until [`CtCache::materialize_shared`] copies them in
    /// (copy-on-write). Tags/mask/tables are always slab-resident —
    /// they diverge per session under eviction.
    shared_src: Option<Arc<SharedPrefix>>,
}

impl CtCache {
    pub fn new(cfg: CacheConfig) -> CtCache {
        let (l, c, hkv, dh, b) = (cfg.layers, cfg.capacity, cfg.hkv, cfg.dh, cfg.buf_slots);
        let g = cfg.groups();
        CtCache {
            tables: (0..l).map(|_| LayerTable::new(c, cfg.block_size)).collect(),
            k_codes: vec![0; l * c * hkv * dh],
            k_scales: vec![0.0; l * c * hkv * g],
            v_codes: vec![0; l * c * hkv * dh],
            v_scales: vec![0.0; l * c * hkv * g],
            tags: vec![0; l * c],
            mask: vec![0.0; l * c],
            buf_k: vec![0.0; l * b * hkv * dh],
            buf_v: vec![0.0; l * b * hkv * dh],
            buf_mask: vec![0.0; l * b],
            segments: Vec::new(),
            buffered: Vec::new(),
            packed_bits_written: 0.0,
            tokens_written: 0,
            shared_len: 0,
            shared_src: None,
            cfg,
        }
    }

    /// Tokens in the read-only shared-prefix region (0 = none).
    pub fn shared_len(&self) -> usize {
        self.shared_len
    }

    /// Mark slots `0..n` as a shared prefix region (used after a
    /// snapshot restore re-links a still-active attachment). The slots
    /// must all be live in every layer.
    pub fn set_shared_len(&mut self, n: usize) {
        debug_assert!(self
            .tables
            .iter()
            .all(|t| (0..n).all(|s| t.slot_segment[s] >= 0)));
        self.shared_len = n;
    }

    /// Copy-on-write completed: the region is privately owned now.
    /// Aliased caches must [`CtCache::materialize_shared`] first — the
    /// slab rows are stale until then.
    pub fn clear_shared(&mut self) {
        debug_assert!(
            self.shared_src.is_none(),
            "clear_shared before materialize_shared would expose stale slab rows"
        );
        self.shared_len = 0;
    }

    /// Engine view of the slabs. For an aliased shared region the view
    /// carries the resident payload rows ([`SharedQuantRows`]) so the
    /// engine reads — or, batched, gathers from one physical copy —
    /// the shared codes/scales without them ever being memcpy'd into
    /// this cache.
    pub fn view(&self) -> QuantCache<'_> {
        let shared = self.shared_src.as_ref().and_then(|sp| match &sp.payload {
            PrefixPayload::Quant { full_len, k_codes, k_scales, v_codes, v_scales, .. } => {
                Some(SharedQuantRows {
                    id: sp.id(),
                    len: self.shared_len,
                    full_len: *full_len,
                    k_codes,
                    k_scales,
                    v_codes,
                    v_scales,
                })
            }
            PrefixPayload::Fp32 { .. } => None,
        });
        QuantCache {
            capacity: self.cfg.capacity,
            k_codes: &self.k_codes,
            k_scales: &self.k_scales,
            v_codes: &self.v_codes,
            v_scales: &self.v_scales,
            tags: &self.tags,
            mask: &self.mask,
            buf_k: &self.buf_k,
            buf_v: &self.buf_v,
            buf_mask: &self.buf_mask,
            shared,
        }
    }

    /// Index of the next free ring-buffer slot (what the decode step gets
    /// as `buf_idx`).
    pub fn buf_fill(&self) -> usize {
        self.buffered.len()
    }

    /// Total live quantized slots in layer 0 (layers may diverge slightly
    /// through per-layer k-means; layer 0 is the reporting reference).
    pub fn live_tokens(&self) -> usize {
        self.tables[0].live_slots()
    }

    pub fn live_tokens_layer(&self, l: usize) -> usize {
        self.tables[l].live_slots()
    }

    /// Open a new thought segment at CoT position `pos`.
    pub fn open_segment(&mut self, thought: Thought, pos: usize) -> usize {
        let id = self.segments.len();
        self.segments.push(SegmentInfo {
            id,
            thought,
            start_pos: pos,
            end_pos: pos,
            evict_level: 0,
        });
        id
    }

    /// Quantize the prompt K/V (layer-major `[L, P, Hkv, Dh]`, post-RoPE)
    /// into the cache as **Reasoning** thoughts at `prec` (paper treats
    /// prefill tokens as R type, §6.1).
    pub fn write_prefill(&mut self, k: &[f32], v: &[f32], p_len: usize, prec: Precision) {
        let seg = self.open_segment(Thought::Reasoning, 0);
        self.write_prefill_range(k, v, p_len, 0, p_len, prec, seg);
    }

    /// Quantize prefill positions `from..to` into the (already open)
    /// prefill segment — the **private tail** half of a shared-prefix
    /// prefill, also the body of [`CtCache::write_prefill`]. `k`/`v`
    /// cover the whole prompt (`[L, p_len, Hkv*Dh]`).
    pub fn write_prefill_range(
        &mut self,
        k: &[f32],
        v: &[f32],
        p_len: usize,
        from: usize,
        to: usize,
        prec: Precision,
        seg: usize,
    ) {
        self.write_prefill_slab(k, v, 0, p_len, from, to, prec, seg);
    }

    /// Chunked-prefill variant of [`CtCache::write_prefill_range`]:
    /// `k`/`v` hold **only** positions `[from, to)` (chunk-local layout
    /// `[L, to - from, Hkv*Dh]`), quantized at their absolute prompt
    /// positions. Writing `0..p_len` in any chunking produces slabs
    /// bit-identical to one [`CtCache::write_prefill`] call (the write
    /// sequence per position is unchanged).
    pub fn write_prefill_chunk(
        &mut self,
        k: &[f32],
        v: &[f32],
        from: usize,
        to: usize,
        prec: Precision,
        seg: usize,
    ) {
        self.write_prefill_slab(k, v, from, to - from, from, to, prec, seg);
    }

    /// Shared body: `k`/`v` cover positions `[slab_start,
    /// slab_start + slab_len)`; positions `[from, to)` of that window
    /// are quantized into `seg`.
    fn write_prefill_slab(
        &mut self,
        k: &[f32],
        v: &[f32],
        slab_start: usize,
        slab_len: usize,
        from: usize,
        to: usize,
        prec: Precision,
        seg: usize,
    ) {
        debug_assert!(slab_start <= from && to <= slab_start + slab_len);
        let kvd = self.cfg.kv_dim();
        for pos in from..to {
            for l in 0..self.cfg.layers {
                let base = (l * slab_len + (pos - slab_start)) * kvd;
                self.write_slot(l, seg, Thought::Reasoning, pos, prec,
                                &k[base..base + kvd], &v[base..base + kvd])
                    .expect("prefill exceeds cache capacity");
            }
        }
        if to > from {
            self.segments[seg].end_pos = to;
        }
        self.tokens_written += (to - from) as u64;
    }

    /// **Shared-attach** half of a shared-prefix prefill: place the
    /// first `n` prefill tokens from an already-quantized payload
    /// (`[L, full_len, ...]` layout) instead of re-quantizing them,
    /// marking the region read-only. Must run on a fresh cache; returns
    /// the prefill segment id so the caller can write the private tail
    /// into it. The resulting slabs are bit-identical to a full
    /// [`CtCache::write_prefill`] of the same tokens (deterministic
    /// quantization), so sharing never changes the decode stream.
    pub fn attach_prefix(
        &mut self,
        payload: &crate::kvcache::PrefixPayload,
        n: usize,
    ) -> Result<usize, String> {
        self.attach_prefix_impl(payload, n, true)
    }

    /// Zero-copy variant of [`CtCache::attach_prefix`]: place the CT
    /// metadata (tables, segment, tags, mask, accounting) for the first
    /// `n` prefix tokens but leave the codes/scales **in the resident
    /// shared payload** — the engine reads them through
    /// [`SharedQuantRows`] and the PR-4 attach memcpy disappears from
    /// the hot path. The region stays read-only until copy-on-write
    /// ([`CtCache::materialize_shared`] + [`CtCache::clear_shared`]).
    pub fn attach_prefix_alias(
        &mut self,
        sp: Arc<SharedPrefix>,
        n: usize,
    ) -> Result<usize, String> {
        let seg = self.attach_prefix_impl(&sp.payload, n, false)?;
        self.shared_src = Some(sp);
        Ok(seg)
    }

    fn attach_prefix_impl(
        &mut self,
        payload: &crate::kvcache::PrefixPayload,
        n: usize,
        copy_payload: bool,
    ) -> Result<usize, String> {
        let crate::kvcache::PrefixPayload::Quant {
            full_len,
            k_codes,
            k_scales,
            v_codes,
            v_scales,
            tags,
        } = payload
        else {
            return Err("fp32 payload attached to a quant cache".into());
        };
        let full_len = *full_len;
        if n > full_len || n > self.cfg.capacity {
            return Err(format!("attach of {n} tokens exceeds payload/capacity"));
        }
        if !self.segments.is_empty() || self.tables[0].allocated_blocks() != 0 {
            return Err("attach_prefix requires a fresh cache".into());
        }
        let (c, kvd) = (self.cfg.capacity, self.cfg.kv_dim());
        let sc = self.cfg.hkv * self.cfg.groups();
        if k_codes.len() != full_len * self.cfg.layers * kvd
            || k_scales.len() != full_len * self.cfg.layers * sc
        {
            return Err("inconsistent prefix payload shape".into());
        }
        let seg = self.open_segment(Thought::Reasoning, 0);
        for pos in 0..n {
            for l in 0..self.cfg.layers {
                let place = self.tables[l]
                    .place(Thought::Reasoning, seg, pos)
                    .ok_or("prefix exceeds cache capacity")?;
                let slot = place.slot;
                debug_assert_eq!(slot, pos, "fresh cache places prefill sequentially");
                if copy_payload {
                    let src_c = (l * full_len + pos) * kvd;
                    let dst_c = (l * c + slot) * kvd;
                    let src_s = (l * full_len + pos) * sc;
                    let dst_s = (l * c + slot) * sc;
                    self.k_codes[dst_c..dst_c + kvd]
                        .copy_from_slice(&k_codes[src_c..src_c + kvd]);
                    self.v_codes[dst_c..dst_c + kvd]
                        .copy_from_slice(&v_codes[src_c..src_c + kvd]);
                    self.k_scales[dst_s..dst_s + sc]
                        .copy_from_slice(&k_scales[src_s..src_s + sc]);
                    self.v_scales[dst_s..dst_s + sc]
                        .copy_from_slice(&v_scales[src_s..src_s + sc]);
                }
                let tag = tags[l * full_len + pos];
                self.tags[l * c + slot] = tag;
                self.mask[l * c + slot] = 1.0;
                if l == 0 {
                    self.packed_bits_written += 2.0
                        * kvd as f64
                        * crate::quant::packed_bits_per_elem(Precision::from_tag(tag));
                }
            }
        }
        self.segments[seg].end_pos = n;
        self.tokens_written += n as u64;
        self.shared_len = n;
        Ok(seg)
    }

    /// Copy the aliased payload rows into this cache's own slabs — the
    /// memcpy half of copy-on-write, run once per session at most,
    /// right before [`CtCache::clear_shared`]. No-op when the region
    /// was attached by copy (or there is none). The shared region is
    /// read-only until CoW, so slots `0..shared_len` still hold
    /// positions `0..shared_len` in every layer.
    pub fn materialize_shared(&mut self) {
        let Some(sp) = self.shared_src.take() else {
            return;
        };
        let PrefixPayload::Quant {
            full_len,
            k_codes,
            k_scales,
            v_codes,
            v_scales,
            ..
        } = &sp.payload
        else {
            return;
        };
        let full_len = *full_len;
        let (c, kvd) = (self.cfg.capacity, self.cfg.kv_dim());
        let sc = self.cfg.hkv * self.cfg.groups();
        for l in 0..self.cfg.layers {
            for slot in 0..self.shared_len {
                let src_c = (l * full_len + slot) * kvd;
                let dst_c = (l * c + slot) * kvd;
                let src_s = (l * full_len + slot) * sc;
                let dst_s = (l * c + slot) * sc;
                self.k_codes[dst_c..dst_c + kvd].copy_from_slice(&k_codes[src_c..src_c + kvd]);
                self.v_codes[dst_c..dst_c + kvd].copy_from_slice(&v_codes[src_c..src_c + kvd]);
                self.k_scales[dst_s..dst_s + sc].copy_from_slice(&k_scales[src_s..src_s + sc]);
                self.v_scales[dst_s..dst_s + sc].copy_from_slice(&v_scales[src_s..src_s + sc]);
            }
        }
    }

    /// Export the first `n` prefill tokens as a shareable payload — the
    /// publish half of prefix sharing. Valid right after
    /// [`CtCache::write_prefill`] (slots `0..n` hold positions `0..n`
    /// in every layer); returns None once eviction or decode writes
    /// have touched the region.
    pub fn export_prefix(&self, n: usize) -> Option<crate::kvcache::PrefixPayload> {
        let (c, kvd) = (self.cfg.capacity, self.cfg.kv_dim());
        let sc = self.cfg.hkv * self.cfg.groups();
        // an aliased cache doesn't hold the shared rows in its slabs
        // (and an attached session never publishes anyway)
        if n == 0 || n > c || self.shared_src.is_some() {
            return None;
        }
        for t in &self.tables {
            for slot in 0..n {
                if t.slot_pos[slot] != slot as i32 {
                    return None; // region no longer the pristine prefill
                }
            }
        }
        let mut k_codes = Vec::with_capacity(self.cfg.layers * n * kvd);
        let mut v_codes = Vec::with_capacity(self.cfg.layers * n * kvd);
        let mut k_scales = Vec::with_capacity(self.cfg.layers * n * sc);
        let mut v_scales = Vec::with_capacity(self.cfg.layers * n * sc);
        let mut tags = Vec::with_capacity(self.cfg.layers * n);
        for l in 0..self.cfg.layers {
            for slot in 0..n {
                let cb = (l * c + slot) * kvd;
                let sb = (l * c + slot) * sc;
                k_codes.extend_from_slice(&self.k_codes[cb..cb + kvd]);
                v_codes.extend_from_slice(&self.v_codes[cb..cb + kvd]);
                k_scales.extend_from_slice(&self.k_scales[sb..sb + sc]);
                v_scales.extend_from_slice(&self.v_scales[sb..sb + sc]);
                tags.push(self.tags[l * c + slot]);
            }
        }
        Some(crate::kvcache::PrefixPayload::Quant {
            full_len: n,
            k_codes,
            k_scales,
            v_codes,
            v_scales,
            tags,
        })
    }

    /// Stash one decode token in the fp ring buffer. Returns true if the
    /// buffer is full **after** the push — caller should `flush_buffer`
    /// before the next decode step.
    ///
    /// `new_k`/`new_v` are `[L, Hkv, Dh]` from the decode step.
    pub fn push_token(
        &mut self,
        new_k: &[f32],
        new_v: &[f32],
        pos: usize,
        segment: usize,
        thought: Thought,
    ) -> bool {
        let idx = self.buffered.len();
        assert!(idx < self.cfg.buf_slots, "buffer overflow: flush first");
        let kvd = self.cfg.kv_dim();
        let b = self.cfg.buf_slots;
        for l in 0..self.cfg.layers {
            let dst = (l * b + idx) * kvd;
            let src = l * kvd;
            self.buf_k[dst..dst + kvd].copy_from_slice(&new_k[src..src + kvd]);
            self.buf_v[dst..dst + kvd].copy_from_slice(&new_v[src..src + kvd]);
            self.buf_mask[l * b + idx] = 1.0;
        }
        self.buffered.push(BufToken { pos, segment, thought });
        self.segments[segment].end_pos = pos + 1;
        self.tokens_written += 1;
        self.buffered.len() == self.cfg.buf_slots
    }

    /// Group-quantize every buffered token at its thought's precision and
    /// place it via CT. Returns Err(tokens_that_did_not_fit) if the slab is
    /// exhausted — the coordinator must evict (TBE case 2) and retry.
    pub fn flush_buffer(&mut self, psi: &dyn Fn(Thought) -> Precision) -> Result<(), usize> {
        let kvd = self.cfg.kv_dim();
        let b = self.cfg.buf_slots;
        let toks = std::mem::take(&mut self.buffered);
        for (idx, t) in toks.iter().enumerate() {
            let prec = psi(t.thought);
            // Per-token atomicity across layers: if any layer cannot place,
            // un-write the layers already written for this token, re-buffer
            // the remainder, and report how many tokens did not fit.
            let mut written: Vec<(usize, SlotId)> = Vec::with_capacity(self.cfg.layers);
            let mut ok = true;
            for l in 0..self.cfg.layers {
                let src = (l * b + idx) * kvd;
                let k = self.buf_k[src..src + kvd].to_vec();
                let v = self.buf_v[src..src + kvd].to_vec();
                match self.write_slot(l, t.segment, t.thought, t.pos, prec, &k, &v) {
                    Some(slot) => written.push((l, slot)),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                for (l, slot) in written {
                    self.tables[l].soft_evict(slot);
                    self.mask[l * self.cfg.capacity + slot] = 0.0;
                }
                let remaining = toks.len() - idx;
                self.buffered = toks[idx..].to_vec();
                self.recompact_buffer(&toks[idx..].to_vec(), idx);
                return Err(remaining);
            }
        }
        for l in 0..self.cfg.layers {
            for i in 0..b {
                self.buf_mask[l * b + i] = 0.0;
            }
        }
        Ok(())
    }

    fn recompact_buffer(&mut self, toks: &[BufToken], from_idx: usize) {
        let kvd = self.cfg.kv_dim();
        let b = self.cfg.buf_slots;
        for l in 0..self.cfg.layers {
            for (new_i, _t) in toks.iter().enumerate() {
                let old = (l * b + from_idx + new_i) * kvd;
                let new = (l * b + new_i) * kvd;
                let (kf, vf): (Vec<f32>, Vec<f32>) = (
                    self.buf_k[old..old + kvd].to_vec(),
                    self.buf_v[old..old + kvd].to_vec(),
                );
                self.buf_k[new..new + kvd].copy_from_slice(&kf);
                self.buf_v[new..new + kvd].copy_from_slice(&vf);
            }
            for i in 0..b {
                self.buf_mask[l * b + i] = if i < toks.len() { 1.0 } else { 0.0 };
            }
        }
    }

    /// Quantize one token's K/V into a CT-chosen slot of layer `l`.
    /// Returns the slot, or None when no slot is available.
    fn write_slot(
        &mut self,
        l: usize,
        segment: usize,
        thought: Thought,
        pos: usize,
        prec: Precision,
        k: &[f32],
        v: &[f32],
    ) -> Option<SlotId> {
        let place = self.tables[l].place(thought, segment, pos)?;
        let slot = place.slot;
        let (c, kvd, g) = (self.cfg.capacity, self.cfg.kv_dim(), self.cfg.groups());
        let code_base = (l * c + slot) * kvd;
        let scale_base = (l * c + slot) * self.cfg.hkv * g;
        quant_groups(k, prec, &mut self.k_codes[code_base..code_base + kvd],
                     &mut self.k_scales[scale_base..scale_base + self.cfg.hkv * g]);
        quant_groups(v, prec, &mut self.v_codes[code_base..code_base + kvd],
                     &mut self.v_scales[scale_base..scale_base + self.cfg.hkv * g]);
        self.tags[l * c + slot] = prec.tag();
        self.mask[l * c + slot] = 1.0;
        if l == 0 {
            self.packed_bits_written +=
                2.0 * kvd as f64 * crate::quant::packed_bits_per_elem(prec);
        }
        Some(slot)
    }

    /// TBE soft eviction of `slots` in layer `l` (mask drops to 0; payload
    /// stays until a same-thought token reclaims the slot). Callers must
    /// not target the read-only shared-prefix region — privatize
    /// (copy-on-write) first or filter those slots out.
    pub fn soft_evict_slots(&mut self, l: usize, slots: &[SlotId]) {
        let c = self.cfg.capacity;
        for &s in slots {
            debug_assert!(
                s >= self.shared_len,
                "evicting shared-prefix slot {s} without copy-on-write"
            );
            self.tables[l].soft_evict(s);
            self.mask[l * c + s] = 0.0;
        }
    }

    /// Dequantized post-RoPE key of a live slot (k-means input for pi).
    pub fn dequant_key(&self, l: usize, slot: SlotId) -> Vec<f32> {
        let (c, kvd, g) = (self.cfg.capacity, self.cfg.kv_dim(), self.cfg.groups());
        let code_base = (l * c + slot) * kvd;
        let scale_base = (l * c + slot) * self.cfg.hkv * g;
        let prec = Precision::from_tag(self.tags[l * c + slot]);
        let mut out = vec![0f32; kvd];
        dequant_groups(
            &self.k_codes[code_base..code_base + kvd],
            &self.k_scales[scale_base..scale_base + self.cfg.hkv * g],
            prec,
            &mut out,
        );
        out
    }

    /// Average packed precision (bits/element) over everything written —
    /// the paper's "average precision of 3.x bits" metric.
    pub fn avg_bits_written(&self) -> f64 {
        if self.tokens_written == 0 {
            return 0.0;
        }
        self.packed_bits_written / (self.tokens_written as f64 * 2.0 * self.cfg.kv_dim() as f64)
    }

    /// Memory footprint (bytes) of the *live* cache under packed accounting,
    /// including the fp32 ring buffer.
    pub fn packed_bytes_live(&self) -> f64 {
        let kvd = self.cfg.kv_dim() as f64;
        let mut bits = 0.0;
        let c = self.cfg.capacity;
        for l in 0..self.cfg.layers {
            for slot in self.tables[l].live_slot_ids() {
                let prec = Precision::from_tag(self.tags[l * c + slot]);
                bits += 2.0 * kvd * crate::quant::packed_bits_per_elem(prec);
            }
        }
        let buf_bytes =
            (self.cfg.layers * self.buffered.len() * 2 * self.cfg.kv_dim() * 4) as f64;
        bits / 8.0 + buf_bytes
    }

    /// Exact host bytes [`CtCache::snapshot_state`] will occupy
    /// (same formula as [`CtSnapshot::host_bytes`]), computed without
    /// building the snapshot — so the swap pool can be reserved *before*
    /// paying for the copy, and a snapshot that will not fit costs O(1).
    pub fn snapshot_host_bytes(&self) -> u64 {
        let kvd = self.cfg.kv_dim() as u64;
        let sc = (self.cfg.hkv * self.cfg.groups()) as u64;
        let mut n = 0u64;
        for t in &self.tables {
            // per live slot: slot id (4) + tag (1) + K/V codes + K/V scales
            n += t.live_slots() as u64 * (4 + 1 + 2 * kvd + 8 * sc);
            n += t.blocks.len() as u64 * (self.cfg.block_size as u64 * 8 + 64);
            n += t.capacity as u64 * 8;
        }
        n += (self.cfg.layers * self.buffered.len()) as u64 * kvd * 8; // B_buf K+V f32
        n += self.buffered.len() as u64 * 24;
        n += self.segments.len() as u64 * 40;
        n
    }

    /// Copy the complete live state into a compact host-side image
    /// (suspend-to-host preemption). The cache itself is untouched.
    pub fn snapshot_state(&self) -> CtSnapshot {
        let (c, kvd) = (self.cfg.capacity, self.cfg.kv_dim());
        let sc = self.cfg.hkv * self.cfg.groups(); // scales per slot
        // aliased shared rows live in the resident payload, not the
        // slabs — the snapshot overlays them so a restore (into a cache
        // with no attachment) is self-contained
        let overlay = self.shared_src.as_ref().and_then(|sp| match &sp.payload {
            PrefixPayload::Quant { full_len, k_codes, k_scales, v_codes, v_scales, .. } => Some((
                *full_len,
                k_codes.as_slice(),
                k_scales.as_slice(),
                v_codes.as_slice(),
                v_scales.as_slice(),
            )),
            PrefixPayload::Fp32 { .. } => None,
        });
        let mut layers = Vec::with_capacity(self.cfg.layers);
        for l in 0..self.cfg.layers {
            let slots = self.tables[l].live_slot_ids();
            let mut ls = CtLayerSnapshot {
                slots: slots.iter().map(|&s| s as u32).collect(),
                tags: Vec::with_capacity(slots.len()),
                k_codes: Vec::with_capacity(slots.len() * kvd),
                k_scales: Vec::with_capacity(slots.len() * sc),
                v_codes: Vec::with_capacity(slots.len() * kvd),
                v_scales: Vec::with_capacity(slots.len() * sc),
            };
            for &s in &slots {
                ls.tags.push(self.tags[l * c + s]);
                if s < self.shared_len {
                    if let Some((fl, pk, pks, pv, pvs)) = overlay {
                        let cb = (l * fl + s) * kvd;
                        let sb = (l * fl + s) * sc;
                        ls.k_codes.extend_from_slice(&pk[cb..cb + kvd]);
                        ls.k_scales.extend_from_slice(&pks[sb..sb + sc]);
                        ls.v_codes.extend_from_slice(&pv[cb..cb + kvd]);
                        ls.v_scales.extend_from_slice(&pvs[sb..sb + sc]);
                        continue;
                    }
                }
                let cb = (l * c + s) * kvd;
                let sb = (l * c + s) * sc;
                ls.k_codes.extend_from_slice(&self.k_codes[cb..cb + kvd]);
                ls.k_scales.extend_from_slice(&self.k_scales[sb..sb + sc]);
                ls.v_codes.extend_from_slice(&self.v_codes[cb..cb + kvd]);
                ls.v_scales.extend_from_slice(&self.v_scales[sb..sb + sc]);
            }
            layers.push(ls);
        }
        let (fill, b) = (self.buffered.len(), self.cfg.buf_slots);
        let mut buf_k = Vec::with_capacity(self.cfg.layers * fill * kvd);
        let mut buf_v = Vec::with_capacity(self.cfg.layers * fill * kvd);
        for l in 0..self.cfg.layers {
            for i in 0..fill {
                let src = (l * b + i) * kvd;
                buf_k.extend_from_slice(&self.buf_k[src..src + kvd]);
                buf_v.extend_from_slice(&self.buf_v[src..src + kvd]);
            }
        }
        CtSnapshot {
            cfg: self.cfg.clone(),
            tables: self.tables.clone(),
            segments: self.segments.clone(),
            layers,
            buffered: self
                .buffered
                .iter()
                .map(|t| (t.pos, t.segment, t.thought))
                .collect(),
            buf_k,
            buf_v,
            packed_bits_written: self.packed_bits_written,
            tokens_written: self.tokens_written,
        }
    }

    /// Load a [`CtSnapshot`] into this (same-geometry) cache, replacing
    /// its entire state. Dead slots are zeroed rather than restored —
    /// the mask-gated kernel never reads them, so the decode stream is
    /// unchanged. Errors if the geometry differs or the image is
    /// internally inconsistent.
    pub fn restore_state(&mut self, snap: CtSnapshot) -> Result<(), String> {
        if snap.cfg != self.cfg {
            return Err(format!(
                "snapshot geometry {:?} does not match cache geometry {:?}",
                snap.cfg, self.cfg
            ));
        }
        let (c, kvd) = (self.cfg.capacity, self.cfg.kv_dim());
        let sc = self.cfg.hkv * self.cfg.groups();
        self.k_codes.fill(0);
        self.k_scales.fill(0.0);
        self.v_codes.fill(0);
        self.v_scales.fill(0.0);
        self.tags.fill(0);
        self.mask.fill(0.0);
        self.buf_k.fill(0.0);
        self.buf_v.fill(0.0);
        self.buf_mask.fill(0.0);
        self.tables = snap.tables;
        self.segments = snap.segments;
        for (l, ls) in snap.layers.iter().enumerate() {
            let n = ls.slots.len();
            if ls.tags.len() != n
                || ls.k_codes.len() != n * kvd
                || ls.k_scales.len() != n * sc
                || ls.v_codes.len() != n * kvd
                || ls.v_scales.len() != n * sc
            {
                return Err(format!("layer {l}: inconsistent snapshot payload"));
            }
            for (i, &s32) in ls.slots.iter().enumerate() {
                let s = s32 as usize;
                if s >= c {
                    return Err(format!("layer {l}: slot {s} out of range"));
                }
                let cb = (l * c + s) * kvd;
                let sb = (l * c + s) * sc;
                self.k_codes[cb..cb + kvd].copy_from_slice(&ls.k_codes[i * kvd..(i + 1) * kvd]);
                self.k_scales[sb..sb + sc].copy_from_slice(&ls.k_scales[i * sc..(i + 1) * sc]);
                self.v_codes[cb..cb + kvd].copy_from_slice(&ls.v_codes[i * kvd..(i + 1) * kvd]);
                self.v_scales[sb..sb + sc].copy_from_slice(&ls.v_scales[i * sc..(i + 1) * sc]);
                self.tags[l * c + s] = ls.tags[i];
                self.mask[l * c + s] = 1.0;
            }
        }
        let (fill, b) = (snap.buffered.len(), self.cfg.buf_slots);
        if snap.buf_k.len() != self.cfg.layers * fill * kvd
            || snap.buf_v.len() != self.cfg.layers * fill * kvd
            || fill > b
        {
            return Err("inconsistent buffer residue in snapshot".into());
        }
        for l in 0..self.cfg.layers {
            for i in 0..fill {
                let dst = (l * b + i) * kvd;
                let src = (l * fill + i) * kvd;
                self.buf_k[dst..dst + kvd].copy_from_slice(&snap.buf_k[src..src + kvd]);
                self.buf_v[dst..dst + kvd].copy_from_slice(&snap.buf_v[src..src + kvd]);
                self.buf_mask[l * b + i] = 1.0;
            }
        }
        self.buffered = snap
            .buffered
            .iter()
            .map(|&(pos, segment, thought)| BufToken { pos, segment, thought })
            .collect();
        self.packed_bits_written = snap.packed_bits_written;
        self.tokens_written = snap.tokens_written;
        // a still-active shared attachment is re-linked by the session
        // after the restore (Session::rebuild_from -> reattach_prefix);
        // the snapshot materialized any aliased rows, so the restored
        // cache owns its slabs outright
        self.shared_len = 0;
        self.shared_src = None;
        self.check_invariants()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        let c = self.cfg.capacity;
        for (l, t) in self.tables.iter().enumerate() {
            t.check_invariants()?;
            // the read-only shared prefix region must stay fully live
            for slot in 0..self.shared_len {
                if t.slot_segment[slot] < 0 {
                    return Err(format!("layer {l}: shared-prefix slot {slot} evicted"));
                }
            }
            for slot in 0..c {
                let live = t.slot_segment[slot] >= 0;
                let m = self.mask[l * c + slot];
                if live && m != 1.0 {
                    return Err(format!("layer {l} slot {slot}: live but mask {m}"));
                }
                if !live && m != 0.0 {
                    return Err(format!("layer {l} slot {slot}: dead but mask {m}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            layers: 2,
            capacity: 64,
            block_size: 8,
            hkv: 2,
            dh: 32,
            buf_slots: 16,
        }
    }

    fn rand_kv(rng: &mut Rng, cfg: &CacheConfig) -> (Vec<f32>, Vec<f32>) {
        let n = cfg.layers * cfg.kv_dim();
        let mut k = vec![0f32; n];
        let mut v = vec![0f32; n];
        rng.fill_normal_f32(&mut k, 0.0, 1.0);
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        (k, v)
    }

    #[test]
    fn push_flush_roundtrip() {
        let cfg = cfg();
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(1);
        let seg = cache.open_segment(Thought::Reasoning, 0);
        let psi = |_t: Thought| Precision::Fp8;
        for i in 0..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            let full = cache.push_token(&k, &v, i, seg, Thought::Reasoning);
            assert_eq!(full, i == 15);
        }
        cache.flush_buffer(&psi).unwrap();
        assert_eq!(cache.live_tokens(), 16);
        assert_eq!(cache.buf_fill(), 0);
        cache.check_invariants().unwrap();
        // mask slab agrees
        let live_mask = cache.mask[..cfg.capacity].iter().filter(|&&m| m == 1.0).count();
        assert_eq!(live_mask, 16);
    }

    #[test]
    fn dequant_key_tracks_quantizer() {
        let cfg = cfg();
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(2);
        let seg = cache.open_segment(Thought::Execution, 0);
        let (k, v) = rand_kv(&mut rng, &cfg);
        cache.push_token(&k, &v, 0, seg, Thought::Execution);
        // force a flush of the single token
        for i in 1..16 {
            let (k2, v2) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k2, &v2, i, seg, Thought::Execution);
        }
        cache.flush_buffer(&|_| Precision::Fp8).unwrap();
        // slot 0 of layer 0 holds token 0
        let deq = cache.dequant_key(0, 0);
        let err: f32 = deq
            .iter()
            .zip(&k[..cfg.kv_dim()])
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / deq.len() as f32;
        assert!(err < 0.05, "fp8 roundtrip err {err}");
    }

    #[test]
    fn eviction_drops_mask_and_reuse_restores() {
        let cfg = cfg();
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(3);
        let seg = cache.open_segment(Thought::Transition, 0);
        for i in 0..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, i, seg, Thought::Transition);
        }
        cache.flush_buffer(&|_| Precision::Ternary).unwrap();
        let before = cache.live_tokens();
        cache.soft_evict_slots(0, &[0, 1, 2]);
        cache.soft_evict_slots(1, &[0, 1, 2]);
        assert_eq!(cache.live_tokens(), before - 3);
        cache.check_invariants().unwrap();
        // new same-thought tokens reuse the slots in place
        let seg2 = cache.open_segment(Thought::Transition, 128);
        for i in 0..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, 128 + i, seg2, Thought::Transition);
        }
        cache.flush_buffer(&|_| Precision::Ternary).unwrap();
        assert!(cache.tables[0].reuse_count >= 3);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn flush_fails_when_full_then_recovers() {
        let cfg = CacheConfig { capacity: 16, ..cfg() };
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(4);
        let seg = cache.open_segment(Thought::Reasoning, 0);
        for i in 0..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, i, seg, Thought::Reasoning);
        }
        cache.flush_buffer(&|_| Precision::Nvfp4).unwrap();
        assert_eq!(cache.live_tokens(), 16);
        // cache totally full: next flush must fail...
        let seg2 = cache.open_segment(Thought::Reasoning, 16);
        for i in 0..4 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, 16 + i, seg2, Thought::Reasoning);
        }
        let e = cache.flush_buffer(&|_| Precision::Nvfp4);
        assert!(e.is_err());
        // ...until TBE frees room
        let slots: Vec<_> = cache.tables[0].segment_slots(seg)[..8].to_vec();
        cache.soft_evict_slots(0, &slots);
        let slots1: Vec<_> = cache.tables[1].segment_slots(seg)[..8].to_vec();
        cache.soft_evict_slots(1, &slots1);
        cache.flush_buffer(&|_| Precision::Nvfp4).unwrap();
        cache.check_invariants().unwrap();
    }

    #[test]
    fn avg_bits_reflects_mixture() {
        let cfg = cfg();
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(5);
        let psi = |t: Thought| match t {
            Thought::Transition => Precision::Ternary,
            _ => Precision::Nvfp4,
        };
        let seg = cache.open_segment(Thought::Reasoning, 0);
        for i in 0..8 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, i, seg, Thought::Reasoning);
        }
        let seg2 = cache.open_segment(Thought::Transition, 8);
        for i in 8..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, i, seg2, Thought::Transition);
        }
        cache.flush_buffer(&psi).unwrap();
        let bits = cache.avg_bits_written();
        assert!(bits > 2.5 && bits < 4.6, "avg bits {bits}");
    }

    #[test]
    fn snapshot_restore_roundtrips_bit_exactly() {
        let cfg = cfg();
        let mut cache = CtCache::new(cfg.clone());
        let mut rng = Rng::new(11);
        let psi = |t: Thought| match t {
            Thought::Transition => Precision::Ternary,
            Thought::Execution => Precision::Nvfp4,
            Thought::Reasoning => Precision::Fp8,
        };
        // mixed history: two segments, a flush, evictions, and a partial
        // buffer left in place (the B_buf residue the snapshot must carry)
        let seg = cache.open_segment(Thought::Reasoning, 0);
        for i in 0..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, i, seg, Thought::Reasoning);
        }
        cache.flush_buffer(&psi).unwrap();
        cache.soft_evict_slots(0, &[1, 3]);
        cache.soft_evict_slots(1, &[1, 3]);
        let seg2 = cache.open_segment(Thought::Execution, 16);
        for i in 0..5 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            cache.push_token(&k, &v, 16 + i, seg2, Thought::Execution);
        }
        let snap = cache.snapshot_state();
        assert!(snap.host_bytes() > 0);
        assert_eq!(snap.buffered.len(), 5);

        let mut fresh = CtCache::new(cfg.clone());
        fresh.restore_state(snap.clone()).unwrap();
        assert_eq!(fresh.live_tokens(), cache.live_tokens());
        assert_eq!(fresh.buf_fill(), cache.buf_fill());
        assert_eq!(fresh.mask, cache.mask);
        assert_eq!(fresh.buf_mask, cache.buf_mask);
        assert_eq!(fresh.segments, cache.segments);
        assert_eq!(fresh.tables, cache.tables);
        // re-snapshotting the restored cache must give the identical image
        assert_eq!(fresh.snapshot_state(), snap);
        // and the restored cache must keep working: flush the residue
        fresh.check_invariants().unwrap();
        for i in 5..16 {
            let (k, v) = rand_kv(&mut rng, &cfg);
            fresh.push_token(&k, &v, 16 + i, seg2, Thought::Execution);
        }
        fresh.flush_buffer(&psi).unwrap();
        fresh.check_invariants().unwrap();
    }

    #[test]
    fn restore_rejects_geometry_mismatch() {
        let cache = CtCache::new(cfg());
        let snap = cache.snapshot_state();
        let mut other = CtCache::new(CacheConfig { capacity: 128, ..cfg() });
        assert!(other.restore_state(snap).is_err());
    }

    /// Prefix sharing must be invisible to the decode stream: attaching
    /// an exported payload + quantizing only the tail reproduces the
    /// exact slabs (codes, scales, tags, masks, tables, accounting) of
    /// a full prefill.
    #[test]
    fn export_attach_prefix_bit_identical() {
        let cfg = cfg();
        let mut rng = Rng::new(21);
        let p_len = 24;
        let kvd = cfg.kv_dim();
        let mut k = vec![0f32; cfg.layers * p_len * kvd];
        let mut v = vec![0f32; cfg.layers * p_len * kvd];
        rng.fill_normal_f32(&mut k, 0.0, 1.0);
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        let mut full = CtCache::new(cfg.clone());
        full.write_prefill(&k, &v, p_len, Precision::Nvfp4);
        let n = 16; // block-aligned shared prefix
        let payload = full.export_prefix(n).expect("pristine region exports");

        let mut shared = CtCache::new(cfg.clone());
        let seg = shared.attach_prefix(&payload, n).unwrap();
        shared.write_prefill_range(&k, &v, p_len, n, p_len, Precision::Nvfp4, seg);
        assert_eq!(shared.shared_len(), n);
        assert_eq!(shared.k_codes, full.k_codes);
        assert_eq!(shared.v_codes, full.v_codes);
        assert_eq!(shared.k_scales, full.k_scales);
        assert_eq!(shared.v_scales, full.v_scales);
        assert_eq!(shared.tags, full.tags);
        assert_eq!(shared.mask, full.mask);
        assert_eq!(shared.tables, full.tables);
        assert_eq!(shared.segments, full.segments);
        assert!((shared.packed_bits_written - full.packed_bits_written).abs() < 1e-6);
        assert_eq!(shared.tokens_written, full.tokens_written);
        shared.check_invariants().unwrap();
        // attach demands a fresh cache
        assert!(shared.attach_prefix(&payload, n).is_err());
        // copy-on-write clears the marker; eviction then reaches the slots
        shared.clear_shared();
        shared.soft_evict_slots(0, &[0, 1]);
        shared.soft_evict_slots(1, &[0, 1]);
        shared.check_invariants().unwrap();
    }

    /// The zero-copy alias attach must be observationally identical to
    /// the copying attach: same metadata slabs, same snapshot image,
    /// shared rows readable through the view, and materializing
    /// (copy-on-write) reproduces the copied slabs bit-exactly.
    #[test]
    fn alias_attach_matches_copying_attach() {
        use crate::kvcache::{BlockPool, PrefixGeom, PrefixIndex};
        let cfg = cfg();
        let mut rng = Rng::new(23);
        let p_len = 24;
        let kvd = cfg.kv_dim();
        let mut k = vec![0f32; cfg.layers * p_len * kvd];
        let mut v = vec![0f32; cfg.layers * p_len * kvd];
        rng.fill_normal_f32(&mut k, 0.0, 1.0);
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        let mut full = CtCache::new(cfg.clone());
        full.write_prefill(&k, &v, p_len, Precision::Nvfp4);
        let n = 16;
        let payload = full.export_prefix(n).expect("pristine region exports");
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(pool, 8);
        let geom = PrefixGeom {
            kind: "quant",
            layers: cfg.layers,
            hkv: cfg.hkv,
            dh: cfg.dh,
            prec_tag: Precision::Nvfp4.tag(),
        };
        let tokens: Vec<i32> = (0..n as i32).collect();
        let att = idx.publish(&tokens, geom, payload).expect("publish");

        let mut copied = CtCache::new(cfg.clone());
        let seg_c = copied.attach_prefix(att.payload(), n).unwrap();
        copied.write_prefill_range(&k, &v, p_len, n, p_len, Precision::Nvfp4, seg_c);

        let mut aliased = CtCache::new(cfg.clone());
        let seg_a = aliased.attach_prefix_alias(att.shared_arc(), n).unwrap();
        assert_eq!(seg_a, seg_c);
        aliased.write_prefill_range(&k, &v, p_len, n, p_len, Precision::Nvfp4, seg_a);

        // metadata is slab-resident either way
        assert_eq!(aliased.tags, copied.tags);
        assert_eq!(aliased.mask, copied.mask);
        assert_eq!(aliased.tables, copied.tables);
        assert_eq!(aliased.segments, copied.segments);
        assert_eq!(aliased.tokens_written, copied.tokens_written);
        aliased.check_invariants().unwrap();
        // the view exposes the resident rows, bit-equal to the copy
        let view = aliased.view();
        let sh = view.shared.expect("aliased view advertises shared rows");
        assert_eq!((sh.len, sh.full_len), (n, n));
        let pr = &sh.k_codes[(sh.full_len + 3) * kvd..][..kvd]; // layer 1, slot 3
        let sr = &copied.k_codes[(cfg.capacity + 3) * kvd..][..kvd];
        assert_eq!(pr, sr);
        // an aliased cache never exports (its slabs lack the rows)
        assert!(aliased.export_prefix(n).is_none());
        // suspend-to-host overlays the payload: identical images
        assert_eq!(aliased.snapshot_state(), copied.snapshot_state());
        // copy-on-write: materialize then clear — full bit-identity
        aliased.materialize_shared();
        assert!(aliased.view().shared.is_none());
        assert_eq!(aliased.k_codes, copied.k_codes);
        assert_eq!(aliased.v_codes, copied.v_codes);
        assert_eq!(aliased.k_scales, copied.k_scales);
        assert_eq!(aliased.v_scales, copied.v_scales);
        aliased.clear_shared();
        aliased.check_invariants().unwrap();
    }

    #[test]
    fn property_mask_always_consistent() {
        prop::check(25, |g| {
            let cfg = CacheConfig {
                layers: 2,
                capacity: 32,
                block_size: 8,
                hkv: 1,
                dh: 16,
                buf_slots: 16,
            };
            let mut cache = CtCache::new(cfg.clone());
            let mut pos = 0usize;
            let mut seg = cache.open_segment(Thought::Reasoning, 0);
            let psi = |t: Thought| match t {
                Thought::Transition => Precision::Ternary,
                Thought::Execution => Precision::Nvfp4,
                Thought::Reasoning => Precision::Fp8,
            };
            for _ in 0..g.usize(10, 60) {
                if g.chance(0.08) {
                    let th = *g.pick(&Thought::ALL);
                    seg = cache.open_segment(th, pos);
                }
                let th = cache.segments[seg].thought;
                let n = cfg.layers * cfg.kv_dim();
                let k = g.vec_normal_f32(n, 0.0, 1.0);
                let v = g.vec_normal_f32(n, 0.0, 1.0);
                let full = cache.push_token(&k, &v, pos, seg, th);
                pos += 1;
                if full {
                    // evict (like TBE case 2) until the flush fits
                    let mut guard = 0;
                    while cache.flush_buffer(&psi).is_err() {
                        for l in 0..cfg.layers {
                            let live = cache.tables[l].live_slot_ids();
                            let take = (live.len() / 2).max(1).min(live.len());
                            cache.soft_evict_slots(l, &live[..take]);
                        }
                        guard += 1;
                        if guard > 8 {
                            return Err("flush never succeeded".into());
                        }
                    }
                }
                cache.check_invariants()?;
            }
            Ok(())
        });
    }
}
