//! [`KvBackend`]: the unified cache abstraction behind every compression
//! mode (alloc / append / evict / decode-view / bytes-used / live-tokens).
//!
//! Before this trait existed, [`crate::coordinator::Session`] carried a
//! closed `CacheState` enum and duplicated the decode-step plumbing once
//! per cache family. Now the session drives one generic path —
//!
//! ```text
//!   make_room -> Engine::decode(view()) -> absorb
//! ```
//!
//! — and the policy machinery lives with the cache it manages:
//!
//! * [`QuantBackend`] — [`CtCache`] + TBQ precision assignment + optional
//!   TBE eviction + thought classifier (+ optional PM-KVQ requant
//!   schedule). Serves ThinKV, the ThinKV ablations, KIVI and PM-KVQ.
//! * [`Fp32Backend`] — [`Fp32Cache`] + a boxed
//!   [`EvictionPolicy`](crate::baselines::eviction::EvictionPolicy).
//!   Serves FullKV and every eviction baseline (H2O, R-KV, RaaS, ...).
//!
//! The byte-accounting methods ([`KvBackend::bytes_used`],
//! [`KvBackend::admission_bytes`], [`KvBackend::step_headroom_bytes`])
//! are what the memory-aware scheduler charges against the global
//! [`BlockPool`](super::BlockPool): packed live bytes for the quantized
//! cache, f32 live bytes for the baseline cache, both including the
//! full-precision ring buffer.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::baselines::eviction::{
    filter_guarded, EvictionPolicy, PolicyKind, PosAttn, RetentionCounters, RetentionEvent,
    RetentionTrace,
};
use crate::baselines::quant_baselines::PmKvq;
use crate::compress::tbe::{Tbe, TbeStats};
use crate::compress::tbq::Tbq;
use crate::metrics::Breakdown;
use crate::model::ModelConfig;
use crate::quant::packed_bits_per_elem;
use crate::runtime::{CacheView, DecodeOut, PrefillOut};
use crate::thought::classifier::Classifier;
use crate::thought::sparsity_per_layer;

use super::prefix::{AttachedPrefix, PrefixGeom, PrefixPayload};
use super::swap::{Fp32Snapshot, KvSnapshot, QuantSnapshot, SnapshotPayload};
use super::{CtCache, Fp32Cache, Thought};

/// Relative threshold for "non-negligible" attention (1% of row max,
/// paper fn. 2) used by the sparsity -> classifier feed.
const SPARSITY_REL_THRESHOLD: f32 = 0.01;

/// Bytes one token occupies in the full-precision ring buffer, across all
/// layers (K and V, f32). This bounds the footprint growth of any single
/// decode step, so it doubles as the scheduler's per-step reserve.
fn fp32_token_bytes(layers: usize, kv_dim: usize) -> u64 {
    (layers * 2 * kv_dim * 4) as u64
}

/// Compatibility key for **cross-session batched decode**: two sessions
/// may share one fused [`crate::runtime::DecodeEngine::decode_batch`]
/// call only when their decode steps run the same compiled executable —
/// i.e. the same cache family and the same compiled capacity. The
/// scheduler groups runnable sessions by this key when forming a decode
/// batch ([`crate::coordinator::Scheduler::next_batch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    /// Cache family (`"quant"` / `"fp32"`) — selects the decode-HLO
    /// family, mirroring [`KvBackend::kind`].
    pub kind: &'static str,
    /// Compiled cache capacity — selects the artifact within the family.
    pub capacity: usize,
}

/// The unified per-request cache backend the session decode loop drives.
///
/// One object = one request's cache plus the policy that manages it.
/// Implementations must be `Send`: sessions migrate between decode
/// workers at chunk granularity.
///
/// # Example
///
/// Build a quantized backend, snapshot it, and restore the snapshot
/// into a fresh backend of the same shape (the suspend-to-host
/// preemption round trip — no engine or artifacts needed):
///
/// ```
/// use thinkv::compress::tbq::{PrecisionAssignment, Tbq};
/// use thinkv::kvcache::{CacheConfig, CtCache, KvBackend, QuantBackend};
/// use thinkv::thought::classifier::{Classifier, ClassifierConfig};
///
/// let cfg = CacheConfig {
///     layers: 2, capacity: 64, block_size: 8, hkv: 1, dh: 16, buf_slots: 16,
/// };
/// let mk = || QuantBackend::new(
///     CtCache::new(cfg.clone()),
///     Tbq::new(PrecisionAssignment::r4e4t2()),
///     None, // no TBE
///     Classifier::new(ClassifierConfig::default()),
///     None, // no PM-KVQ
/// );
/// let backend = mk();
/// assert_eq!(backend.kind(), "quant");
/// let snap = backend.snapshot().unwrap();
/// assert!(snap.bytes > 0, "even an empty cache has CT metadata");
/// let mut resumed = mk();
/// resumed.restore(snap).unwrap();
/// assert_eq!(resumed.live_tokens(), 0);
/// ```
pub trait KvBackend: Send {
    /// Short label for diagnostics ("quant" / "fp32").
    fn kind(&self) -> &'static str;

    /// Batched-decode compatibility key: sessions whose backends return
    /// equal keys run the same compiled decode executable and may be
    /// advanced together by one fused
    /// [`crate::runtime::DecodeEngine::decode_batch`] call.
    fn compat_key(&self) -> BatchKey;

    /// Ingest the prompt K/V produced by engine prefill (alloc + append).
    fn write_prefill(&mut self, pf: &PrefillOut, p_len: usize);

    /// Chunked-prefill ingest: write prompt positions `[from, to)` from
    /// a chunk-local slab (`[L, to - from, Hkv*Dh]`, post-RoPE) at their
    /// absolute positions. Chunks must arrive in order, starting at 0
    /// (or at the shared-attach boundary established by
    /// [`KvBackend::begin_prefill_shared`]); covering `0..p_len` in any
    /// chunking leaves the cache bit-identical to one
    /// [`KvBackend::write_prefill`] call.
    fn write_prefill_chunk(&mut self, k: &[f32], v: &[f32], from: usize, to: usize);

    /// Shared-attach half of a **chunked** prefill: place the resident
    /// payload's tokens from `att` and mark the region read-only,
    /// exactly as [`KvBackend::write_prefill_shared`] does before its
    /// private-tail write. Returns the number of tokens attached — the
    /// prompt position the first engine-computed chunk starts at.
    fn begin_prefill_shared(&mut self, att: Arc<AttachedPrefix>, p_len: usize) -> Result<usize>;

    /// Cross-session prefix-sharing geometry key: two sessions may share
    /// prefill payload only when their backends would have produced
    /// byte-identical blocks for the same tokens.
    fn prefix_geom(&self) -> PrefixGeom;

    /// [`KvBackend::write_prefill`] split for prefix sharing:
    /// **shared-attach** the first `att.attach_len()` tokens from the
    /// resident payload (no re-quantization, region marked read-only),
    /// then write only the **private tail** from `pf`. The slabs end up
    /// bit-identical to an unshared prefill of the same tokens.
    ///
    /// Provided in terms of the chunked primitives — one
    /// [`KvBackend::begin_prefill_shared`] plus a single tail chunk
    /// through [`KvBackend::write_prefill_chunk`] — so the whole-prompt
    /// and chunked shared prefills are the same code path.
    fn write_prefill_shared(
        &mut self,
        pf: &PrefillOut,
        p_len: usize,
        att: Arc<AttachedPrefix>,
    ) -> Result<()> {
        let n = self.begin_prefill_shared(att, p_len)?;
        if n >= p_len {
            return Ok(());
        }
        // re-pack the tail into the chunk-local layout the chunk write
        // expects ([L, p_len - n, kv])
        let g = self.prefix_geom();
        let kvd = g.hkv * g.dh;
        let len = p_len - n;
        let mut k = Vec::with_capacity(g.layers * len * kvd);
        let mut v = Vec::with_capacity(g.layers * len * kvd);
        for l in 0..g.layers {
            let base = (l * p_len + n) * kvd;
            k.extend_from_slice(&pf.k[base..base + len * kvd]);
            v.extend_from_slice(&pf.v[base..base + len * kvd]);
        }
        self.write_prefill_chunk(&k, &v, n, p_len);
        Ok(())
    }

    /// Export the first `n` prefill tokens as a shareable payload (the
    /// publish half). None once the region is no longer the pristine
    /// prefill.
    fn export_prefix(&self, n: usize) -> Option<PrefixPayload>;

    /// Re-link a prefix attachment after [`KvBackend::restore`] (the
    /// suspend/resume path of a sharing session) or after a publish, so
    /// byte accounting and the read-only marker stay consistent.
    fn reattach_prefix(&mut self, att: Arc<AttachedPrefix>);

    /// Tokens currently in the read-only shared-prefix region (0 = no
    /// sharing, or already privatized by copy-on-write).
    fn shared_prefix_tokens(&self) -> usize;

    /// Make room for the upcoming decode step: flush the ring buffer if
    /// full, evicting (TBE case 2 / baseline policy) as needed. `pos` is
    /// the current CoT position. Errors only when the cache is exhausted
    /// beyond what the policy can reclaim.
    fn make_room(&mut self, pos: usize, bd: &mut Breakdown) -> Result<()>;

    /// Engine-facing borrowed view of the cache slabs (decode-view).
    fn view(&self) -> CacheView<'_>;

    /// Tokens currently staged in the full-precision ring buffer.
    fn buf_fill(&self) -> usize;

    /// Absorb one decode step's outputs: classification / policy stats,
    /// the new token's K/V (append), budget enforcement (evict), and any
    /// progressive requantization.
    fn absorb(
        &mut self,
        out: &DecodeOut,
        pos: usize,
        model: &ModelConfig,
        bd: &mut Breakdown,
    ) -> Result<()>;

    /// Live cached tokens including the ring buffer (memory reporting).
    fn live_tokens(&self) -> usize;

    /// Byte-accurate live footprint under packed accounting — the unit
    /// the scheduler charges against the global `BlockPool`.
    fn bytes_used(&self) -> u64;

    /// Upper bound on `bytes_used` growth across one decode step (one
    /// token lands in the f32 ring buffer; flushes and evictions only
    /// shrink the footprint).
    fn step_headroom_bytes(&self) -> u64;

    /// Upper bound on `bytes_used` right after prefill plus one full ring
    /// buffer — the admission reserve for this request.
    fn admission_bytes(&self, prefill_len: usize) -> u64;

    /// Average packed precision written so far (bits/element).
    fn avg_bits(&self) -> f64;

    /// CT in-place slot reuses (quant backend only).
    fn ct_reuses(&self) -> u64 {
        0
    }

    /// TBE counters (quant backend with TBE only).
    fn tbe_stats(&self) -> Option<TbeStats> {
        None
    }

    /// (gather_calls, gather_bytes, gather_nanos) — fp32 backend only.
    fn gather_stats(&self) -> (u64, u64, u64) {
        (0, 0, 0)
    }

    /// Display name of the retention policy managing this cache:
    /// the arena policy's [`EvictionPolicy::name`] for the fp32 backend,
    /// `"TBE"`/`"none"` for the quantized cache.
    fn policy_name(&self) -> &'static str {
        "none"
    }

    /// Retention counters accumulated so far (evictions, never-
    /// materialized skips, live retained bytes). Zeros for backends
    /// without a live policy arena.
    fn retention(&self) -> RetentionCounters {
        RetentionCounters::default()
    }

    /// Exact host bytes a [`KvBackend::snapshot`] taken right now would
    /// occupy, computed without building it — so the caller can reserve
    /// the [`SwapPool`](super::SwapPool) *first* and a snapshot that
    /// will not fit costs O(1) instead of a discarded full copy.
    fn snapshot_bytes(&self) -> u64;

    /// Copy the complete cache + policy state into a host-side image
    /// (suspend-to-host preemption). The backend is left untouched; the
    /// caller decides whether to drop it (swap-out) or keep running.
    /// `KvSnapshot::bytes` is the host footprint the
    /// [`SwapPool`](super::SwapPool) charges (always equal to
    /// [`KvBackend::snapshot_bytes`] at capture time); `device_bytes`
    /// records [`KvBackend::bytes_used`] so swap-in can re-reserve the
    /// block pool byte-accurately.
    fn snapshot(&self) -> Result<KvSnapshot>;

    /// Load a snapshot taken by [`KvBackend::snapshot`] into this
    /// (freshly built, same-geometry) backend so decoding resumes
    /// exactly where the snapshot was captured — identical token
    /// stream, zero recompute steps. Errors on a kind or geometry
    /// mismatch.
    fn restore(&mut self, snap: KvSnapshot) -> Result<()>;
}

// ---------------------------------------------------------------------------
// Quantized backend: CtCache + TBQ (+ TBE, classifier, optional PM-KVQ)
// ---------------------------------------------------------------------------

/// ThinKV / KIVI / PM-KVQ backend over the Continuous-Thinking cache.
pub struct QuantBackend {
    cache: CtCache,
    tbq: Tbq,
    tbe: Option<Tbe>,
    classifier: Classifier,
    cur_thought: Thought,
    cur_segment: usize,
    pmkvq: Option<PmKvq>,
    /// Cross-session shared-prefix attachment (delta-only accounting +
    /// copy-on-write state); None = unshared session.
    att: Option<Arc<AttachedPrefix>>,
}

impl QuantBackend {
    pub fn new(
        cache: CtCache,
        tbq: Tbq,
        tbe: Option<Tbe>,
        classifier: Classifier,
        pmkvq: Option<PmKvq>,
    ) -> QuantBackend {
        QuantBackend {
            cache,
            tbq,
            tbe,
            classifier,
            cur_thought: Thought::Reasoning,
            cur_segment: 0,
            pmkvq,
            att: None,
        }
    }

    /// Bytes the active shared attachment keeps off this session's bill.
    fn shared_discount(&self) -> u64 {
        match &self.att {
            Some(a) if a.is_active() => a.bytes(),
            _ => 0,
        }
    }

    /// First write past the shared boundary: privatize via copy-on-write
    /// (reserve the prefix bytes, materialize the aliased payload rows
    /// into this cache's slabs — the only memcpy sharing ever pays, and
    /// only here — drop the shared ref, lift the read-only marker). A
    /// denied CoW (pool full) leaves the region protected — eviction
    /// then works around it. Takes the fields directly so callers can
    /// hold disjoint borrows of `self`.
    fn cow_privatize(att: &Option<Arc<AttachedPrefix>>, cache: &mut CtCache) {
        if let Some(a) = att {
            if a.is_active() && a.try_privatize() {
                cache.materialize_shared();
                cache.clear_shared();
            }
        }
    }
}

impl KvBackend for QuantBackend {
    fn kind(&self) -> &'static str {
        "quant"
    }

    fn compat_key(&self) -> BatchKey {
        BatchKey { kind: self.kind(), capacity: self.cache.cfg.capacity }
    }

    fn write_prefill(&mut self, pf: &PrefillOut, p_len: usize) {
        // prefill tokens are R thoughts (paper §6.1)
        let prec = self.tbq.psi(Thought::Reasoning);
        self.cache.write_prefill(&pf.k, &pf.v, p_len, prec);
    }

    fn write_prefill_chunk(&mut self, k: &[f32], v: &[f32], from: usize, to: usize) {
        let prec = self.tbq.psi(Thought::Reasoning);
        // the prefill segment is opened by the first chunk (or by the
        // shared attach) and is always segment 0 on a fresh cache
        let seg = if self.cache.segments.is_empty() {
            debug_assert_eq!(from, 0, "first chunk of an unshared prefill starts at 0");
            self.cache.open_segment(Thought::Reasoning, 0)
        } else {
            0
        };
        self.cache.write_prefill_chunk(k, v, from, to, prec, seg);
    }

    fn begin_prefill_shared(&mut self, att: Arc<AttachedPrefix>, p_len: usize) -> Result<usize> {
        let n = att.attach_len().min(p_len);
        // zero-copy attach: metadata (tags / mask / tables) is written,
        // but the payload rows stay in the one resident copy — the
        // engine view carries them and fused decode gathers them via
        // block tables, so the attach-time memcpy of PR 4 is gone
        self.cache
            .attach_prefix_alias(att.shared_arc(), n)
            .map_err(|e| anyhow::anyhow!("prefix attach: {e}"))?;
        att.note_alias();
        self.att = Some(att);
        Ok(n)
    }

    fn prefix_geom(&self) -> PrefixGeom {
        PrefixGeom {
            kind: "quant",
            layers: self.cache.cfg.layers,
            hkv: self.cache.cfg.hkv,
            dh: self.cache.cfg.dh,
            prec_tag: self.tbq.psi(Thought::Reasoning).tag(),
        }
    }

    fn export_prefix(&self, n: usize) -> Option<PrefixPayload> {
        self.cache.export_prefix(n)
    }

    fn reattach_prefix(&mut self, att: Arc<AttachedPrefix>) {
        if att.is_active() {
            self.cache.set_shared_len(att.attach_len());
        }
        self.att = Some(att);
    }

    fn shared_prefix_tokens(&self) -> usize {
        self.cache.shared_len()
    }

    fn make_room(&mut self, pos: usize, bd: &mut Breakdown) -> Result<()> {
        if self.cache.segments.is_empty() {
            bail!("prefill did not initialize segments");
        }
        if self.cur_segment == 0 && self.cache.segments.len() == 1 {
            // first decode token: open the initial decode segment
            self.cur_segment = self.cache.open_segment(self.cur_thought, pos);
        }
        // flush the fp ring buffer if full (group quantization, TBQ)
        if self.cache.buf_fill() == self.cache.cfg.buf_slots {
            let tq = std::time::Instant::now();
            let tbq = &self.tbq;
            let psi = |t: Thought| tbq.psi(t);
            if self.cache.flush_buffer(&psi).is_err() {
                // allocation pressure is about to evict — the first
                // write past a shared prefix boundary, so CoW first
                Self::cow_privatize(&self.att, &mut self.cache);
                // TBE case 2 under allocation pressure
                if let Some(tbe) = self.tbe.as_mut() {
                    let te = std::time::Instant::now();
                    tbe.ensure_budget(&mut self.cache);
                    bd.tbe_ns += te.elapsed().as_nanos() as u64;
                    bd.tbe_calls += 1;
                }
                if self.cache.flush_buffer(&psi).is_err() {
                    bail!("cache exhausted even after TBE (budget too small for capacity)");
                }
            }
            bd.quant_write_ns += tq.elapsed().as_nanos() as u64;
        }
        Ok(())
    }

    fn view(&self) -> CacheView<'_> {
        CacheView::Quant(self.cache.view())
    }

    fn buf_fill(&self) -> usize {
        self.cache.buf_fill()
    }

    fn absorb(
        &mut self,
        out: &DecodeOut,
        pos: usize,
        model: &ModelConfig,
        bd: &mut Breakdown,
    ) -> Result<()> {
        // sparsity -> classifier
        let tr = std::time::Instant::now();
        let c = self.cache.cfg.capacity;
        let b = self.cache.cfg.buf_slots;
        let span = c + b;
        let mut valid = vec![0f32; model.n_layers * span];
        for l in 0..model.n_layers {
            valid[l * span..l * span + c].copy_from_slice(&self.cache.mask[l * c..(l + 1) * c]);
            valid[l * span + c..(l + 1) * span]
                .copy_from_slice(&self.cache.buf_mask[l * b..(l + 1) * b]);
        }
        let per_layer = sparsity_per_layer(
            &out.probs,
            &valid,
            model.n_layers,
            model.n_heads,
            span,
            SPARSITY_REL_THRESHOLD,
        );
        self.classifier.push_step(&per_layer);
        if self.classifier.due() {
            let closing = self.cur_thought;
            let label = self.classifier.refresh();
            bd.refresh_calls += 1;
            // TBE case 1 at the end of a transition window
            if closing == Thought::Transition {
                if let Some(tbe) = self.tbe.as_mut() {
                    // case 1 anneals every prior segment — the prefill
                    // segment included — so a shared prefix privatizes
                    // (copy-on-write) before the anneal may touch it
                    Self::cow_privatize(&self.att, &mut self.cache);
                    let tt = std::time::Instant::now();
                    tbe.on_transition_end(&mut self.cache, self.cur_segment);
                    bd.tbe_ns += tt.elapsed().as_nanos() as u64;
                    bd.tbe_calls += 1;
                }
            }
            self.cur_thought = label;
            self.cur_segment = self.cache.open_segment(label, pos + 1);
        }
        bd.refresh_ns += tr.elapsed().as_nanos() as u64;

        // push the new token into B_buf
        let tq = std::time::Instant::now();
        self.cache
            .push_token(&out.new_k, &out.new_v, pos, self.cur_segment, self.cur_thought);
        bd.quant_write_ns += tq.elapsed().as_nanos() as u64;

        // TBE case 2: budget
        if let Some(tbe) = self.tbe.as_mut() {
            tbe.tick();
            if self.cache.live_tokens() + self.cache.buf_fill() > tbe.cfg.budget {
                // budget pressure may reach the prefill segment: CoW a
                // shared prefix so eviction matches the unshared path
                Self::cow_privatize(&self.att, &mut self.cache);
                let tt = std::time::Instant::now();
                let evicted = tbe.ensure_budget(&mut self.cache);
                bd.tbe_ns += tt.elapsed().as_nanos() as u64;
                if evicted > 0 {
                    bd.tbe_calls += 1;
                }
            }
        }

        // PM-KVQ progressive requantization
        if let Some(pm) = &self.pmkvq {
            if pos % 128 == 0 {
                if pos >= pm.first_demotion_age() {
                    // requantization is about to rewrite the oldest
                    // (prefix) slots in place: copy-on-write first
                    Self::cow_privatize(&self.att, &mut self.cache);
                }
                let tp = std::time::Instant::now();
                pm.apply(&mut self.cache, pos);
                bd.policy_ns += tp.elapsed().as_nanos() as u64;
                bd.policy_calls += 1;
            }
        }
        Ok(())
    }

    fn live_tokens(&self) -> usize {
        self.cache.live_tokens() + self.cache.buf_fill()
    }

    fn bytes_used(&self) -> u64 {
        // an active shared prefix is charged to the index (once,
        // globally), so this session's bill covers only its delta
        (self.cache.packed_bytes_live().ceil() as u64).saturating_sub(self.shared_discount())
    }

    fn step_headroom_bytes(&self) -> u64 {
        fp32_token_bytes(self.cache.cfg.layers, self.cache.cfg.kv_dim())
    }

    fn admission_bytes(&self, prefill_len: usize) -> u64 {
        let cfg = &self.cache.cfg;
        let prec = self.tbq.psi(Thought::Reasoning);
        let prefill_bits = (prefill_len * cfg.layers * 2 * cfg.kv_dim()) as f64
            * packed_bits_per_elem(prec);
        let buf = cfg.buf_slots as u64 * fp32_token_bytes(cfg.layers, cfg.kv_dim());
        (prefill_bits / 8.0).ceil() as u64 + buf
    }

    fn avg_bits(&self) -> f64 {
        self.cache.avg_bits_written()
    }

    fn ct_reuses(&self) -> u64 {
        self.cache.tables.iter().map(|t| t.reuse_count).sum()
    }

    fn tbe_stats(&self) -> Option<TbeStats> {
        self.tbe.as_ref().map(|t| t.stats.clone())
    }

    fn policy_name(&self) -> &'static str {
        if self.tbe.is_some() {
            "TBE"
        } else {
            "none"
        }
    }

    fn snapshot_bytes(&self) -> u64 {
        self.cache.snapshot_host_bytes()
    }

    fn snapshot(&self) -> Result<KvSnapshot> {
        let ct = self.cache.snapshot_state();
        debug_assert_eq!(ct.host_bytes(), self.cache.snapshot_host_bytes());
        Ok(KvSnapshot {
            bytes: ct.host_bytes(),
            device_bytes: self.bytes_used(),
            payload: SnapshotPayload::Quant(Box::new(QuantSnapshot {
                ct,
                classifier: self.classifier.snapshot_state(),
                cur_thought: self.cur_thought,
                cur_segment: self.cur_segment,
                tbe_stats: self.tbe.as_ref().map(|t| t.stats.clone()),
            })),
        })
    }

    fn restore(&mut self, snap: KvSnapshot) -> Result<()> {
        let SnapshotPayload::Quant(q) = snap.payload else {
            bail!("cannot restore an fp32 snapshot into a quant backend");
        };
        let q = *q;
        self.cache
            .restore_state(q.ct)
            .map_err(|e| anyhow::anyhow!("quant restore: {e}"))?;
        self.classifier.restore_state(q.classifier);
        self.cur_thought = q.cur_thought;
        self.cur_segment = q.cur_segment;
        if let (Some(tbe), Some(stats)) = (self.tbe.as_mut(), q.tbe_stats) {
            tbe.stats = stats;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Fp32 backend: Fp32Cache + EvictionPolicy (FullKV and eviction baselines)
// ---------------------------------------------------------------------------

/// FullKV / eviction-baseline backend over the f32 paged cache.
pub struct Fp32Backend {
    cache: Fp32Cache,
    policy: Box<dyn EvictionPolicy>,
    /// Token budget k (`usize::MAX` = unbounded, FullKV).
    budget: usize,
    /// Whether evictions trigger gather-based compaction (R-KV style).
    gather: bool,
    capacity: usize,
    /// Cross-session shared-prefix attachment; None = unshared session.
    att: Option<Arc<AttachedPrefix>>,
    /// Optional retention audit log ([`Fp32Backend::enable_trace`]):
    /// every policy call's inputs and outputs, replayable by
    /// `sim::oracle::replay_divergence`.
    trace: Option<RetentionTrace>,
    /// Positions evicted from the cache so far.
    evicted_ct: u64,
    /// Positions never materialized ([`EvictionPolicy::skip_kv`]).
    skipped_ct: u64,
}

impl Fp32Backend {
    pub fn new(
        cache: Fp32Cache,
        policy: Box<dyn EvictionPolicy>,
        budget: usize,
        gather: bool,
        capacity: usize,
    ) -> Fp32Backend {
        Fp32Backend {
            cache,
            policy,
            budget,
            gather,
            capacity,
            att: None,
            trace: None,
            evicted_ct: 0,
            skipped_ct: 0,
        }
    }

    /// Start recording every retention decision (observe / keep / skip /
    /// select-evictions calls) into a [`RetentionTrace`]. `kind` must
    /// describe the policy this backend runs and `budget` the value it
    /// was built with ([`PolicyKind::build`]) so the sim twin can be
    /// reconstructed in an identical starting state.
    pub fn enable_trace(&mut self, kind: PolicyKind, budget: usize) {
        self.trace = Some(RetentionTrace::new(kind, budget));
    }

    /// Take the recorded audit log; recording stops.
    pub fn take_trace(&mut self) -> Option<RetentionTrace> {
        self.trace.take()
    }

    /// Positions currently resident in the cache slab (sorted; the ring
    /// buffer is not included) — exactly the `live` set the policy's
    /// [`EvictionPolicy::select_evictions`] calls see.
    pub fn live_positions(&self) -> Vec<usize> {
        self.cache.live_positions()
    }

    fn shared_discount(&self) -> u64 {
        match &self.att {
            Some(a) if a.is_active() => a.bytes(),
            _ => 0,
        }
    }

    /// The policy wants to evict `evict` positions. If any fall inside a
    /// shared prefix, privatize it (copy-on-write) so the eviction
    /// matches the unshared path; a denied CoW (pool full) instead
    /// filters the protected positions out and the policy works with
    /// what remains. Takes the fields directly so callers can hold
    /// disjoint borrows of `self` (same shape as the quant backend's
    /// `cow_privatize`).
    fn cow_filter(
        att: &Option<Arc<AttachedPrefix>>,
        cache: &mut Fp32Cache,
        evict: Vec<usize>,
    ) -> Vec<usize> {
        let shared = cache.shared_len();
        if shared == 0 || evict.iter().all(|&p| p >= shared) {
            return evict;
        }
        if let Some(a) = att {
            if a.is_active() && a.try_privatize() {
                cache.materialize_shared();
                cache.clear_shared();
                return evict;
            }
        }
        // denied CoW: the guarded region stays read-only, drop the
        // blocked positions (one shared guarded-region filter — the
        // same helper the quant call-sites gate on)
        filter_guarded(evict, shared).0
    }

    /// Policy eviction honoring a read-only shared prefix: select
    /// normally (privatizing via CoW when the pool allows it); when the
    /// CoW is denied and the filter drops *every* selected position,
    /// re-select among the evictable remainder only — the pinned shared
    /// rows count toward the survivor target — so a denied CoW can
    /// never starve eviction while non-shared victims exist.
    fn select_evictions_shared(&mut self, live: &[usize], target: usize) -> Vec<usize> {
        let evict = self.policy.select_evictions(live, target);
        if let Some(t) = self.trace.as_mut() {
            // record the raw proposal (pre CoW / guard filtering): the
            // replay twin mirrors the policy call, not the cache
            t.events.push(RetentionEvent::Evict {
                live: live.to_vec(),
                target,
                evicted: evict.clone(),
            });
        }
        let evict = Self::cow_filter(&self.att, &mut self.cache, evict);
        if !evict.is_empty() {
            return evict;
        }
        let shared = self.cache.shared_len();
        if shared == 0 {
            return evict; // the policy genuinely refused to evict
        }
        let free = filter_guarded(live.to_vec(), shared).0;
        let free_target = target.saturating_sub(shared);
        let evict = self.policy.select_evictions(&free, free_target);
        if let Some(t) = self.trace.as_mut() {
            t.events.push(RetentionEvent::Evict {
                live: free,
                target: free_target,
                evicted: evict.clone(),
            });
        }
        evict
    }
}

impl KvBackend for Fp32Backend {
    fn kind(&self) -> &'static str {
        "fp32"
    }

    fn compat_key(&self) -> BatchKey {
        BatchKey { kind: self.kind(), capacity: self.capacity }
    }

    fn write_prefill(&mut self, pf: &PrefillOut, p_len: usize) {
        self.cache.write_prefill(&pf.k, &pf.v, p_len);
    }

    fn write_prefill_chunk(&mut self, k: &[f32], v: &[f32], from: usize, to: usize) {
        self.cache.write_prefill_chunk(k, v, from, to);
    }

    fn begin_prefill_shared(&mut self, att: Arc<AttachedPrefix>, p_len: usize) -> Result<usize> {
        let n = att.attach_len().min(p_len);
        // zero-copy attach (see the quant twin): rows stay resident,
        // the view's `shared` field carries them to the engine
        self.cache
            .attach_prefix_alias(att.shared_arc(), n)
            .map_err(|e| anyhow::anyhow!("prefix attach: {e}"))?;
        att.note_alias();
        self.att = Some(att);
        Ok(n)
    }

    fn prefix_geom(&self) -> PrefixGeom {
        PrefixGeom {
            kind: "fp32",
            layers: self.cache.layers,
            hkv: 1,
            dh: self.cache.kv_dim,
            prec_tag: 0,
        }
    }

    fn export_prefix(&self, n: usize) -> Option<PrefixPayload> {
        self.cache.export_prefix(n)
    }

    fn reattach_prefix(&mut self, att: Arc<AttachedPrefix>) {
        if att.is_active() {
            self.cache.set_shared_len(att.attach_len());
        }
        self.att = Some(att);
    }

    fn shared_prefix_tokens(&self) -> usize {
        self.cache.shared_len()
    }

    fn make_room(&mut self, _pos: usize, bd: &mut Breakdown) -> Result<()> {
        if self.cache.buf_fill() == self.cache.buf_slots {
            while self.cache.flush_buffer().is_err() {
                let tp = std::time::Instant::now();
                let live = self.cache.live_positions();
                let target = live.len().saturating_sub(self.cache.buf_slots);
                let evict = self.select_evictions_shared(&live, target);
                if evict.is_empty() {
                    bail!("fp32 cache full and policy refuses to evict");
                }
                self.evicted_ct += evict.len() as u64;
                self.cache.evict_positions(&evict);
                bd.policy_ns += tp.elapsed().as_nanos() as u64;
                bd.policy_calls += 1;
                if self.gather {
                    let tg = std::time::Instant::now();
                    self.cache.compact_gather();
                    bd.gather_ns += tg.elapsed().as_nanos() as u64;
                    bd.gather_calls += 1;
                }
            }
        }
        Ok(())
    }

    fn view(&self) -> CacheView<'_> {
        CacheView::Fp32 {
            capacity: self.capacity,
            k: &self.cache.k,
            v: &self.cache.v,
            mask: &self.cache.mask,
            buf_k: &self.cache.buf_k,
            buf_v: &self.cache.buf_v,
            buf_mask: &self.cache.buf_mask,
            shared: self.cache.shared_rows(),
        }
    }

    fn buf_fill(&self) -> usize {
        self.cache.buf_fill()
    }

    fn absorb(
        &mut self,
        out: &DecodeOut,
        pos: usize,
        model: &ModelConfig,
        bd: &mut Breakdown,
    ) -> Result<()> {
        // feed attention stats to the policy (mean over layers+heads)
        let tp = std::time::Instant::now();
        let span = self.capacity + self.cache.buf_slots;
        let mut pos_attn = Vec::new();
        for slot in 0..self.capacity {
            let p = self.cache.slot_pos[slot];
            if p < 0 {
                continue;
            }
            let mut acc = 0f32;
            for l in 0..model.n_layers {
                for h in 0..model.n_heads {
                    acc += out.probs[(l * model.n_heads + h) * span + slot];
                }
            }
            pos_attn.push((p as usize, acc / (model.n_layers * model.n_heads) as f32));
        }
        let row = PosAttn { step: pos, attn: pos_attn };
        self.policy.observe(&row);
        if let Some(t) = self.trace.as_mut() {
            t.events.push(RetentionEvent::Observe { step: pos, attn: row.attn });
        }
        bd.policy_ns += tp.elapsed().as_nanos() as u64;

        // SkipKV's never-materialize axis: the policy may veto the
        // append outright — the position then consumes neither pool
        // bytes nor a cache row (downstream attention masks treat it
        // exactly like an already-evicted position).
        if self.policy.skip_kv(pos) {
            self.skipped_ct += 1;
            if let Some(t) = self.trace.as_mut() {
                t.events.push(RetentionEvent::Skip { pos });
            }
        } else {
            if let Some(t) = self.trace.as_mut() {
                t.events.push(RetentionEvent::Keep { pos });
            }
            self.cache.push_token(out, pos);
        }

        // budget enforcement
        if self.budget != usize::MAX {
            let live = self.cache.live_positions();
            if live.len() + self.cache.buf_fill() > self.budget {
                let tp = std::time::Instant::now();
                let target = self.budget.saturating_sub(self.cache.buf_fill());
                let evict = self.select_evictions_shared(&live, target);
                if !evict.is_empty() {
                    self.evicted_ct += evict.len() as u64;
                    self.cache.evict_positions(&evict);
                    bd.policy_calls += 1;
                    if self.gather {
                        let tg = std::time::Instant::now();
                        self.cache.compact_gather();
                        bd.gather_ns += tg.elapsed().as_nanos() as u64;
                        bd.gather_calls += 1;
                    }
                }
                bd.policy_ns += tp.elapsed().as_nanos() as u64;
            }
        }
        Ok(())
    }

    fn live_tokens(&self) -> usize {
        self.cache.live_tokens() + self.cache.buf_fill()
    }

    fn bytes_used(&self) -> u64 {
        // an active shared prefix is charged to the index, not here
        self.cache.bytes_live().saturating_sub(self.shared_discount())
    }

    fn step_headroom_bytes(&self) -> u64 {
        fp32_token_bytes(self.cache.layers, self.cache.kv_dim)
    }

    fn admission_bytes(&self, prefill_len: usize) -> u64 {
        (prefill_len + self.cache.buf_slots) as u64
            * fp32_token_bytes(self.cache.layers, self.cache.kv_dim)
    }

    fn avg_bits(&self) -> f64 {
        16.0
    }

    fn gather_stats(&self) -> (u64, u64, u64) {
        (self.cache.gather_calls, self.cache.gather_bytes, self.cache.gather_nanos)
    }

    fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    fn retention(&self) -> RetentionCounters {
        RetentionCounters {
            evicted: self.evicted_ct,
            skipped: self.skipped_ct,
            retained_bytes: self.bytes_used(),
        }
    }

    fn snapshot_bytes(&self) -> u64 {
        self.cache.snapshot_host_bytes()
    }

    fn snapshot(&self) -> Result<KvSnapshot> {
        let cache = self.cache.snapshot_state();
        debug_assert_eq!(cache.host_bytes(), self.cache.snapshot_host_bytes());
        Ok(KvSnapshot {
            bytes: cache.host_bytes(),
            device_bytes: self.bytes_used(),
            payload: SnapshotPayload::Fp32(Box::new(Fp32Snapshot {
                cache,
                policy: self.policy.box_clone(),
            })),
        })
    }

    fn restore(&mut self, snap: KvSnapshot) -> Result<()> {
        let SnapshotPayload::Fp32(f) = snap.payload else {
            bail!("cannot restore a quant snapshot into an fp32 backend");
        };
        let f = *f;
        self.cache
            .restore_state(f.cache)
            .map_err(|e| anyhow::anyhow!("fp32 restore: {e}"))?;
        self.policy = f.policy;
        Ok(())
    }
}
