//! F32 paged cache for FullKV and the eviction-only baselines (H2O, R-KV,
//! RaaS, LazyEviction, SnapKV).
//!
//! Unlike [`super::ct::CtCache`], eviction here leaves *holes* that the
//! baselines must handle the way the original systems do: H2O keeps a
//! circular buffer (contiguous eviction only), R-KV runs **gather-based
//! compaction** (§5.1) whose cost this module measures for Figure 7 /
//! Table 5.

use std::sync::Arc;

use crate::runtime::{DecodeOut, SharedFp32Rows};

use super::block_table::SlotId;
use super::prefix::{PrefixPayload, SharedPrefix};

/// Compact suspend-to-host image of an [`Fp32Cache`]: the live f32
/// rows, the ring-buffer residue, and the gather counters. Unlike the
/// quantized [`CtSnapshot`](super::ct::CtSnapshot) this image is full
/// precision, so it is 10-20x larger per live token — the reason
/// eviction baselines swap poorly (ISSUE 2 motivation).
#[derive(Debug, Clone, PartialEq)]
pub struct Fp32CacheSnapshot {
    pub layers: usize,
    pub capacity: usize,
    pub kv_dim: usize,
    pub buf_slots: usize,
    /// `(slot, CoT position)` of each live slot, ascending by slot.
    pub slots: Vec<(u32, u32)>,
    /// `[L, n, kv_dim]` live K rows.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// CoT positions of the buffered (unflushed) tokens, in push order.
    pub buffered_pos: Vec<usize>,
    /// `[L, fill, kv_dim]` buffered K payload.
    pub buf_k: Vec<f32>,
    pub buf_v: Vec<f32>,
    pub gather_bytes: u64,
    pub gather_calls: u64,
    pub gather_nanos: u64,
}

impl Fp32CacheSnapshot {
    /// Host bytes this snapshot occupies (payload + per-slot metadata).
    pub fn host_bytes(&self) -> u64 {
        self.slots.len() as u64 * 8
            + 4 * (self.k.len() + self.v.len() + self.buf_k.len() + self.buf_v.len()) as u64
            + self.buffered_pos.len() as u64 * 8
    }
}

#[derive(Debug, Clone)]
pub struct Fp32Cache {
    pub layers: usize,
    pub capacity: usize,
    pub kv_dim: usize, // hkv * dh
    pub buf_slots: usize,
    pub k: Vec<f32>,    // [L, C, kv_dim]
    pub v: Vec<f32>,    // [L, C, kv_dim]
    pub mask: Vec<f32>, // [L, C]
    /// CoT position of each slot, -1 = empty (shared across layers: the f32
    /// baselines evict the same positions in every layer, as the originals
    /// do with per-layer identical policies over pooled attention stats).
    pub slot_pos: Vec<i32>, // [C]
    pub buf_k: Vec<f32>,
    pub buf_v: Vec<f32>,
    pub buf_mask: Vec<f32>,
    buffered: usize,
    buffered_pos: Vec<usize>,
    /// Gather statistics (bytes moved by compaction) for the cost model.
    pub gather_bytes: u64,
    pub gather_calls: u64,
    pub gather_nanos: u64,
    /// Slots/positions `0..shared_len` hold a cross-session shared
    /// prefix and are read-only until the backend privatizes them
    /// (copy-on-write). 0 = none. They are front-contiguous and never
    /// evicted while shared, so `compact_gather` leaves them in place.
    shared_len: usize,
    /// When the shared region was attached by **aliasing**
    /// ([`Fp32Cache::attach_prefix_alias`]): the resident entry whose
    /// payload physically holds the K/V rows for slots `0..shared_len`.
    /// The cache's own slabs are stale there until
    /// [`Fp32Cache::materialize_shared`]. Mask/slot_pos are always
    /// slab-resident.
    shared_src: Option<Arc<SharedPrefix>>,
}

impl Fp32Cache {
    pub fn new(layers: usize, capacity: usize, kv_dim: usize, buf_slots: usize) -> Fp32Cache {
        Fp32Cache {
            layers,
            capacity,
            kv_dim,
            buf_slots,
            k: vec![0.0; layers * capacity * kv_dim],
            v: vec![0.0; layers * capacity * kv_dim],
            mask: vec![0.0; layers * capacity],
            slot_pos: vec![-1; capacity],
            buf_k: vec![0.0; layers * buf_slots * kv_dim],
            buf_v: vec![0.0; layers * buf_slots * kv_dim],
            buf_mask: vec![0.0; layers * buf_slots],
            buffered: 0,
            buffered_pos: Vec::new(),
            gather_bytes: 0,
            gather_calls: 0,
            gather_nanos: 0,
            shared_len: 0,
            shared_src: None,
        }
    }

    /// Tokens in the read-only shared-prefix region (0 = none).
    pub fn shared_len(&self) -> usize {
        self.shared_len
    }

    /// Mark slots `0..n` as a shared prefix region (used after a
    /// snapshot restore re-links a still-active attachment).
    pub fn set_shared_len(&mut self, n: usize) {
        debug_assert!((0..n).all(|s| self.slot_pos[s] >= 0));
        self.shared_len = n;
    }

    /// Copy-on-write completed: the region is privately owned now.
    /// Aliased caches must [`Fp32Cache::materialize_shared`] first.
    pub fn clear_shared(&mut self) {
        debug_assert!(
            self.shared_src.is_none(),
            "clear_shared before materialize_shared would expose stale slab rows"
        );
        self.shared_len = 0;
    }

    /// The aliased shared rows for the engine view, when this cache was
    /// attached zero-copy — `None` once materialized (or never aliased).
    pub fn shared_rows(&self) -> Option<SharedFp32Rows<'_>> {
        self.shared_src.as_ref().and_then(|sp| match &sp.payload {
            PrefixPayload::Fp32 { full_len, k, v } => Some(SharedFp32Rows {
                id: sp.id(),
                len: self.shared_len,
                full_len: *full_len,
                k,
                v,
            }),
            PrefixPayload::Quant { .. } => None,
        })
    }

    pub fn buf_fill(&self) -> usize {
        self.buffered
    }

    pub fn live_tokens(&self) -> usize {
        self.slot_pos.iter().filter(|&&p| p >= 0).count()
    }

    /// Live KV bytes (f32 accounting, all layers, including the ring
    /// buffer) — what the scheduler charges against the block pool.
    pub fn bytes_live(&self) -> u64 {
        ((self.live_tokens() + self.buffered) * self.layers * 2 * self.kv_dim * 4) as u64
    }

    /// First free slot, if any.
    pub fn free_slot(&self) -> Option<SlotId> {
        self.slot_pos.iter().position(|&p| p < 0)
    }

    /// Write prompt K/V (`[L, P, kv_dim]`) into slots 0..P.
    pub fn write_prefill(&mut self, k: &[f32], v: &[f32], p_len: usize) {
        self.write_prefill_range(k, v, p_len, 0, p_len);
    }

    /// Write prefill positions `from..to` into their slots — the
    /// private-tail half of a shared-prefix prefill, also the body of
    /// [`Fp32Cache::write_prefill`]. `k`/`v` cover the whole prompt
    /// (`[L, p_len, kv_dim]`).
    pub fn write_prefill_range(
        &mut self,
        k: &[f32],
        v: &[f32],
        p_len: usize,
        from: usize,
        to: usize,
    ) {
        self.write_prefill_slab(k, v, 0, p_len, from, to);
    }

    /// Chunked-prefill variant of [`Fp32Cache::write_prefill_range`]:
    /// `k`/`v` hold **only** positions `[from, to)` (chunk-local layout
    /// `[L, to - from, kv_dim]`), written at their absolute prompt
    /// positions. Writing `0..p_len` in any chunking produces slabs
    /// bit-identical to one [`Fp32Cache::write_prefill`] call.
    pub fn write_prefill_chunk(&mut self, k: &[f32], v: &[f32], from: usize, to: usize) {
        self.write_prefill_slab(k, v, from, to - from, from, to);
    }

    /// Shared body: `k`/`v` cover positions `[slab_start,
    /// slab_start + slab_len)`; positions `[from, to)` of that window
    /// are written to their slots.
    fn write_prefill_slab(
        &mut self,
        k: &[f32],
        v: &[f32],
        slab_start: usize,
        slab_len: usize,
        from: usize,
        to: usize,
    ) {
        assert!(to <= self.capacity && slab_start <= from && to <= slab_start + slab_len);
        let kvd = self.kv_dim;
        for l in 0..self.layers {
            for pos in from..to {
                let src = (l * slab_len + (pos - slab_start)) * kvd;
                self.write_slot_layer(l, pos, &k[src..src + kvd], &v[src..src + kvd]);
            }
        }
        for pos in from..to {
            self.slot_pos[pos] = pos as i32;
        }
    }

    /// Shared-attach half of a shared-prefix prefill: copy the first
    /// `n` rows from an already-computed payload and mark them
    /// read-only. Must run on a fresh cache.
    pub fn attach_prefix(
        &mut self,
        payload: &crate::kvcache::PrefixPayload,
        n: usize,
    ) -> Result<(), String> {
        self.attach_prefix_impl(payload, n, true)
    }

    /// Zero-copy variant of [`Fp32Cache::attach_prefix`]: mark slots
    /// `0..n` live but leave the K/V rows **in the resident shared
    /// payload** — the engine reads them through [`SharedFp32Rows`].
    /// The region stays read-only until copy-on-write
    /// ([`Fp32Cache::materialize_shared`] + [`Fp32Cache::clear_shared`]).
    pub fn attach_prefix_alias(&mut self, sp: Arc<SharedPrefix>, n: usize) -> Result<(), String> {
        self.attach_prefix_impl(&sp.payload, n, false)?;
        self.shared_src = Some(sp);
        Ok(())
    }

    fn attach_prefix_impl(
        &mut self,
        payload: &crate::kvcache::PrefixPayload,
        n: usize,
        copy_payload: bool,
    ) -> Result<(), String> {
        let crate::kvcache::PrefixPayload::Fp32 { full_len, k, v } = payload else {
            return Err("quant payload attached to an fp32 cache".into());
        };
        let full_len = *full_len;
        if n > full_len || n > self.capacity {
            return Err(format!("attach of {n} tokens exceeds payload/capacity"));
        }
        if self.live_tokens() != 0 || self.buffered != 0 {
            return Err("attach_prefix requires a fresh cache".into());
        }
        if k.len() != full_len * self.layers * self.kv_dim {
            return Err("inconsistent prefix payload shape".into());
        }
        for l in 0..self.layers {
            for pos in 0..n {
                if copy_payload {
                    let src = (l * full_len + pos) * self.kv_dim;
                    let (kk, vv) = (
                        k[src..src + self.kv_dim].to_vec(),
                        v[src..src + self.kv_dim].to_vec(),
                    );
                    self.write_slot_layer(l, pos, &kk, &vv);
                } else {
                    self.mask[l * self.capacity + pos] = 1.0;
                }
            }
        }
        for pos in 0..n {
            self.slot_pos[pos] = pos as i32;
        }
        self.shared_len = n;
        Ok(())
    }

    /// Copy the aliased payload rows into this cache's own slabs — the
    /// memcpy half of copy-on-write, right before
    /// [`Fp32Cache::clear_shared`]. No-op when the region was attached
    /// by copy (or there is none).
    pub fn materialize_shared(&mut self) {
        let Some(sp) = self.shared_src.take() else {
            return;
        };
        let PrefixPayload::Fp32 { full_len, k, v } = &sp.payload else {
            return;
        };
        let (full_len, kvd) = (*full_len, self.kv_dim);
        for l in 0..self.layers {
            for pos in 0..self.shared_len {
                let src = (l * full_len + pos) * kvd;
                let dst = (l * self.capacity + pos) * kvd;
                self.k[dst..dst + kvd].copy_from_slice(&k[src..src + kvd]);
                self.v[dst..dst + kvd].copy_from_slice(&v[src..src + kvd]);
            }
        }
    }

    /// Export the first `n` prefill rows as a shareable payload. Valid
    /// while slots `0..n` still hold positions `0..n`.
    pub fn export_prefix(&self, n: usize) -> Option<crate::kvcache::PrefixPayload> {
        // an aliased cache doesn't hold the shared rows in its slabs
        if n == 0 || n > self.capacity || self.shared_src.is_some() {
            return None;
        }
        for slot in 0..n {
            if self.slot_pos[slot] != slot as i32 {
                return None;
            }
        }
        let kvd = self.kv_dim;
        let mut k = Vec::with_capacity(self.layers * n * kvd);
        let mut v = Vec::with_capacity(self.layers * n * kvd);
        for l in 0..self.layers {
            for slot in 0..n {
                let base = (l * self.capacity + slot) * kvd;
                k.extend_from_slice(&self.k[base..base + kvd]);
                v.extend_from_slice(&self.v[base..base + kvd]);
            }
        }
        Some(crate::kvcache::PrefixPayload::Fp32 { full_len: n, k, v })
    }

    fn write_slot_layer(&mut self, l: usize, slot: SlotId, k: &[f32], v: &[f32]) {
        let base = (l * self.capacity + slot) * self.kv_dim;
        self.k[base..base + self.kv_dim].copy_from_slice(k);
        self.v[base..base + self.kv_dim].copy_from_slice(v);
        self.mask[l * self.capacity + slot] = 1.0;
    }

    /// Stash one decode token (`new_k/new_v` are `[L, kv_dim]` from
    /// [`DecodeOut`]); returns true when the buffer is full.
    pub fn push_token(&mut self, out: &DecodeOut, pos: usize) -> bool {
        let idx = self.buffered;
        assert!(idx < self.buf_slots, "flush first");
        for l in 0..self.layers {
            let dst = (l * self.buf_slots + idx) * self.kv_dim;
            let src = l * self.kv_dim;
            self.buf_k[dst..dst + self.kv_dim].copy_from_slice(&out.new_k[src..src + self.kv_dim]);
            self.buf_v[dst..dst + self.kv_dim].copy_from_slice(&out.new_v[src..src + self.kv_dim]);
            self.buf_mask[l * self.buf_slots + idx] = 1.0;
        }
        self.buffered += 1;
        self.buffered_pos.push(pos);
        self.buffered == self.buf_slots
    }

    /// Move buffered tokens into free cache slots. Returns Err(overflow)
    /// if there isn't room — caller evicts then retries.
    pub fn flush_buffer(&mut self) -> Result<(), usize> {
        let free: Vec<SlotId> = (0..self.capacity).filter(|&s| self.slot_pos[s] < 0).collect();
        if free.len() < self.buffered {
            return Err(self.buffered - free.len());
        }
        let take = self.buffered;
        for i in 0..take {
            let slot = free[i];
            for l in 0..self.layers {
                let src = (l * self.buf_slots + i) * self.kv_dim;
                let kk = self.buf_k[src..src + self.kv_dim].to_vec();
                let vv = self.buf_v[src..src + self.kv_dim].to_vec();
                self.write_slot_layer(l, slot, &kk, &vv);
            }
            self.slot_pos[slot] = self.buffered_pos[i] as i32;
        }
        self.buffered = 0;
        self.buffered_pos.clear();
        for l in 0..self.layers {
            for i in 0..self.buf_slots {
                self.buf_mask[l * self.buf_slots + i] = 0.0;
            }
        }
        Ok(())
    }

    /// Evict slots (drop mask + free slot) — leaves holes. Callers must
    /// not target the read-only shared-prefix region — privatize
    /// (copy-on-write) first or filter those slots out.
    pub fn evict_slots(&mut self, slots: &[SlotId]) {
        for &s in slots {
            debug_assert!(
                s >= self.shared_len,
                "evicting shared-prefix slot {s} without copy-on-write"
            );
            self.slot_pos[s] = -1;
            for l in 0..self.layers {
                self.mask[l * self.capacity + s] = 0.0;
            }
        }
    }

    /// Evict by CoT positions (what score-based policies compute).
    pub fn evict_positions(&mut self, positions: &[usize]) {
        let set: std::collections::BTreeSet<i32> =
            positions.iter().map(|&p| p as i32).collect();
        let slots: Vec<SlotId> = (0..self.capacity)
            .filter(|&s| set.contains(&self.slot_pos[s]))
            .collect();
        self.evict_slots(&slots);
    }

    /// Gather-based compaction (R-KV, §5.1): physically move live rows to
    /// the front of the slab. This is the real data movement whose cost the
    /// paper measures — we time it and count bytes for the GPU cost model.
    pub fn compact_gather(&mut self) {
        let t0 = std::time::Instant::now();
        let mut dst = 0usize;
        let mut moved_bytes = 0u64;
        for s in 0..self.capacity {
            if self.slot_pos[s] < 0 {
                continue;
            }
            if s != dst {
                for l in 0..self.layers {
                    let from = (l * self.capacity + s) * self.kv_dim;
                    let to = (l * self.capacity + dst) * self.kv_dim;
                    // copy_within on both K and V slabs
                    self.k.copy_within(from..from + self.kv_dim, to);
                    self.v.copy_within(from..from + self.kv_dim, to);
                    self.mask[l * self.capacity + dst] = 1.0;
                    self.mask[l * self.capacity + s] = 0.0;
                    moved_bytes += (2 * self.kv_dim * 4) as u64;
                }
                self.slot_pos[dst] = self.slot_pos[s];
                self.slot_pos[s] = -1;
            }
            dst += 1;
        }
        self.gather_bytes += moved_bytes;
        self.gather_calls += 1;
        self.gather_nanos += t0.elapsed().as_nanos() as u64;
    }

    /// Positions currently cached (sorted).
    pub fn live_positions(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .slot_pos
            .iter()
            .filter(|&&p| p >= 0)
            .map(|&p| p as usize)
            .collect();
        v.sort_unstable();
        v
    }

    /// Slot currently holding CoT position `pos`.
    pub fn slot_of_pos(&self, pos: usize) -> Option<SlotId> {
        self.slot_pos.iter().position(|&p| p == pos as i32)
    }

    /// Exact host bytes [`Fp32Cache::snapshot_state`] will occupy (same
    /// formula as [`Fp32CacheSnapshot::host_bytes`]), computed without
    /// building the snapshot.
    pub fn snapshot_host_bytes(&self) -> u64 {
        let live = self.live_tokens() as u64;
        let (l, kvd) = (self.layers as u64, self.kv_dim as u64);
        let fill = self.buffered as u64;
        live * 8 + l * live * kvd * 8 + fill * 8 + l * fill * kvd * 8
    }

    /// Copy the complete live state into a compact host-side image
    /// (suspend-to-host preemption). The cache itself is untouched.
    pub fn snapshot_state(&self) -> Fp32CacheSnapshot {
        let kvd = self.kv_dim;
        let live: Vec<SlotId> = (0..self.capacity).filter(|&s| self.slot_pos[s] >= 0).collect();
        // aliased shared rows live in the resident payload, not the
        // slabs — overlay them so a restore is self-contained
        let overlay = self.shared_src.as_ref().and_then(|sp| match &sp.payload {
            PrefixPayload::Fp32 { full_len, k, v } => {
                Some((*full_len, k.as_slice(), v.as_slice()))
            }
            PrefixPayload::Quant { .. } => None,
        });
        let mut k = Vec::with_capacity(self.layers * live.len() * kvd);
        let mut v = Vec::with_capacity(self.layers * live.len() * kvd);
        for l in 0..self.layers {
            for &s in &live {
                if s < self.shared_len {
                    if let Some((fl, pk, pv)) = overlay {
                        let base = (l * fl + s) * kvd;
                        k.extend_from_slice(&pk[base..base + kvd]);
                        v.extend_from_slice(&pv[base..base + kvd]);
                        continue;
                    }
                }
                let base = (l * self.capacity + s) * kvd;
                k.extend_from_slice(&self.k[base..base + kvd]);
                v.extend_from_slice(&self.v[base..base + kvd]);
            }
        }
        let fill = self.buffered;
        let mut buf_k = Vec::with_capacity(self.layers * fill * kvd);
        let mut buf_v = Vec::with_capacity(self.layers * fill * kvd);
        for l in 0..self.layers {
            for i in 0..fill {
                let src = (l * self.buf_slots + i) * kvd;
                buf_k.extend_from_slice(&self.buf_k[src..src + kvd]);
                buf_v.extend_from_slice(&self.buf_v[src..src + kvd]);
            }
        }
        Fp32CacheSnapshot {
            layers: self.layers,
            capacity: self.capacity,
            kv_dim: kvd,
            buf_slots: self.buf_slots,
            slots: live
                .iter()
                .map(|&s| (s as u32, self.slot_pos[s] as u32))
                .collect(),
            k,
            v,
            buffered_pos: self.buffered_pos.clone(),
            buf_k,
            buf_v,
            gather_bytes: self.gather_bytes,
            gather_calls: self.gather_calls,
            gather_nanos: self.gather_nanos,
        }
    }

    /// Load an [`Fp32CacheSnapshot`] into this (same-geometry) cache,
    /// replacing its entire state.
    pub fn restore_state(&mut self, snap: Fp32CacheSnapshot) -> Result<(), String> {
        if snap.layers != self.layers
            || snap.capacity != self.capacity
            || snap.kv_dim != self.kv_dim
            || snap.buf_slots != self.buf_slots
        {
            return Err("fp32 snapshot geometry mismatch".into());
        }
        let kvd = self.kv_dim;
        let n = snap.slots.len();
        let fill = snap.buffered_pos.len();
        if snap.k.len() != self.layers * n * kvd
            || snap.v.len() != self.layers * n * kvd
            || snap.buf_k.len() != self.layers * fill * kvd
            || snap.buf_v.len() != self.layers * fill * kvd
            || fill > self.buf_slots
        {
            return Err("inconsistent fp32 snapshot payload".into());
        }
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.mask.fill(0.0);
        self.slot_pos.fill(-1);
        self.buf_k.fill(0.0);
        self.buf_v.fill(0.0);
        self.buf_mask.fill(0.0);
        for (i, &(s32, pos)) in snap.slots.iter().enumerate() {
            let s = s32 as usize;
            if s >= self.capacity {
                return Err(format!("fp32 snapshot slot {s} out of range"));
            }
            self.slot_pos[s] = pos as i32;
            for l in 0..self.layers {
                let dst = (l * self.capacity + s) * kvd;
                let src = (l * n + i) * kvd;
                self.k[dst..dst + kvd].copy_from_slice(&snap.k[src..src + kvd]);
                self.v[dst..dst + kvd].copy_from_slice(&snap.v[src..src + kvd]);
                self.mask[l * self.capacity + s] = 1.0;
            }
        }
        for l in 0..self.layers {
            for i in 0..fill {
                let dst = (l * self.buf_slots + i) * kvd;
                let src = (l * fill + i) * kvd;
                self.buf_k[dst..dst + kvd].copy_from_slice(&snap.buf_k[src..src + kvd]);
                self.buf_v[dst..dst + kvd].copy_from_slice(&snap.buf_v[src..src + kvd]);
                self.buf_mask[l * self.buf_slots + i] = 1.0;
            }
        }
        self.buffered = fill;
        self.buffered_pos = snap.buffered_pos;
        self.gather_bytes = snap.gather_bytes;
        self.gather_calls = snap.gather_calls;
        self.gather_nanos = snap.gather_nanos;
        // a still-active shared attachment is re-linked by the session
        // after the restore (Session::rebuild_from -> reattach_prefix);
        // the snapshot materialized any aliased rows
        self.shared_len = 0;
        self.shared_src = None;
        self.check_invariants()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        for s in 0..self.capacity {
            let live = self.slot_pos[s] >= 0;
            for l in 0..self.layers {
                let m = self.mask[l * self.capacity + s];
                if live && m != 1.0 {
                    return Err(format!("slot {s} layer {l}: live but mask {m}"));
                }
                if !live && m != 0.0 {
                    return Err(format!("slot {s} layer {l}: dead but mask {m}"));
                }
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        for &p in self.slot_pos.iter().filter(|&&p| p >= 0) {
            if !seen.insert(p) {
                return Err(format!("position {p} cached twice"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk() -> Fp32Cache {
        Fp32Cache::new(2, 32, 8, 16)
    }

    fn fake_out(layers: usize, kv_dim: usize, seed: f32) -> DecodeOut {
        DecodeOut {
            logits: vec![],
            new_k: (0..layers * kv_dim).map(|i| seed + i as f32).collect(),
            new_v: (0..layers * kv_dim).map(|i| -seed - i as f32).collect(),
            probs: vec![],
        }
    }

    #[test]
    fn prefill_then_flush() {
        let mut c = mk();
        let k = vec![1.0; 2 * 4 * 8];
        let v = vec![2.0; 2 * 4 * 8];
        c.write_prefill(&k, &v, 4);
        assert_eq!(c.live_tokens(), 4);
        for i in 0..16 {
            c.push_token(&fake_out(2, 8, i as f32), 4 + i);
        }
        c.flush_buffer().unwrap();
        assert_eq!(c.live_tokens(), 20);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_leaves_holes_compaction_fills_them() {
        let mut c = mk();
        let k = vec![1.0; 2 * 16 * 8];
        c.write_prefill(&k.clone(), &k, 16);
        c.evict_positions(&[1, 3, 5, 7]);
        assert_eq!(c.live_tokens(), 12);
        assert!(c.free_slot().is_some());
        c.compact_gather();
        assert_eq!(c.live_tokens(), 12);
        assert!(c.gather_bytes > 0);
        assert_eq!(c.gather_calls, 1);
        // live slots are now the prefix
        for s in 0..12 {
            assert!(c.slot_pos[s] >= 0);
        }
        for s in 12..32 {
            assert!(c.slot_pos[s] < 0);
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn compaction_preserves_payload() {
        let mut c = Fp32Cache::new(1, 8, 2, 16);
        let k: Vec<f32> = (0..8 * 2).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..8 * 2).map(|i| 100.0 + i as f32).collect();
        c.write_prefill(&k, &v, 8);
        c.evict_positions(&[0, 2]);
        c.compact_gather();
        // position 1's payload must now live at slot 0 or 1 with same data
        let slot = c.slot_of_pos(1).unwrap();
        let base = slot * 2;
        assert_eq!(&c.k[base..base + 2], &[2.0, 3.0]);
        assert_eq!(&c.v[base..base + 2], &[102.0, 103.0]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn flush_overflow_reported() {
        let mut c = Fp32Cache::new(1, 8, 2, 16);
        let k = vec![0.0; 8 * 2];
        c.write_prefill(&k.clone(), &k, 8);
        for i in 0..4 {
            c.push_token(&fake_out(1, 2, i as f32), 8 + i);
        }
        assert_eq!(c.flush_buffer(), Err(4));
        c.evict_positions(&[0, 1, 2, 3]);
        assert!(c.flush_buffer().is_ok());
        c.check_invariants().unwrap();
    }

    #[test]
    fn snapshot_restore_roundtrips_bit_exactly() {
        let mut c = mk();
        let k = vec![1.5; 2 * 8 * 8];
        let v = vec![-2.5; 2 * 8 * 8];
        c.write_prefill(&k, &v, 8);
        c.evict_positions(&[1, 5]);
        for i in 0..3 {
            c.push_token(&fake_out(2, 8, i as f32), 8 + i);
        }
        c.compact_gather();
        let snap = c.snapshot_state();
        assert!(snap.host_bytes() > 0);
        assert_eq!(snap.buffered_pos, vec![8, 9, 10]);

        let mut fresh = Fp32Cache::new(2, 32, 8, 16);
        fresh.restore_state(snap.clone()).unwrap();
        assert_eq!(fresh.live_tokens(), c.live_tokens());
        assert_eq!(fresh.buf_fill(), c.buf_fill());
        assert_eq!(fresh.mask, c.mask);
        assert_eq!(fresh.slot_pos, c.slot_pos);
        assert_eq!(fresh.gather_calls, c.gather_calls);
        assert_eq!(fresh.snapshot_state(), snap);
        // restored cache keeps working
        for i in 3..16 {
            fresh.push_token(&fake_out(2, 8, i as f32), 8 + i);
        }
        fresh.flush_buffer().unwrap();
        fresh.check_invariants().unwrap();
    }

    #[test]
    fn restore_rejects_geometry_mismatch() {
        let c = mk();
        let snap = c.snapshot_state();
        let mut other = Fp32Cache::new(2, 64, 8, 16);
        assert!(other.restore_state(snap).is_err());
    }

    /// Prefix sharing parity: attach + private tail reproduces the exact
    /// slabs of a full prefill, and the shared rows survive compaction.
    #[test]
    fn export_attach_prefix_bit_identical() {
        let mut full = mk();
        let p = 16;
        let k: Vec<f32> = (0..2 * p * 8).map(|i| i as f32 * 0.25).collect();
        let v: Vec<f32> = (0..2 * p * 8).map(|i| -(i as f32) * 0.5).collect();
        full.write_prefill(&k, &v, p);
        let n = 8;
        let payload = full.export_prefix(n).expect("pristine region exports");

        let mut shared = mk();
        shared.attach_prefix(&payload, n).unwrap();
        shared.write_prefill_range(&k, &v, p, n, p);
        assert_eq!(shared.shared_len(), n);
        assert_eq!(shared.k, full.k);
        assert_eq!(shared.v, full.v);
        assert_eq!(shared.mask, full.mask);
        assert_eq!(shared.slot_pos, full.slot_pos);
        shared.check_invariants().unwrap();
        assert!(shared.attach_prefix(&payload, n).is_err(), "attach needs a fresh cache");
        // evicting past the shared boundary + compaction leaves the
        // shared front rows in place
        shared.evict_positions(&[n, n + 1]);
        shared.compact_gather();
        for s in 0..n {
            assert_eq!(shared.slot_pos[s], s as i32, "shared row moved");
        }
        shared.check_invariants().unwrap();
        // copy-on-write clears the marker; eviction then reaches the rows
        shared.clear_shared();
        shared.evict_positions(&[0, 1]);
        shared.check_invariants().unwrap();
    }

    /// The zero-copy alias attach must be observationally identical to
    /// the copying attach: same metadata, same snapshot image, rows
    /// readable through [`Fp32Cache::shared_rows`], and materializing
    /// (copy-on-write) reproduces the copied slabs bit-exactly.
    #[test]
    fn alias_attach_matches_copying_attach() {
        use crate::kvcache::{BlockPool, PrefixGeom, PrefixIndex};
        let mut full = mk();
        let p = 16;
        let k: Vec<f32> = (0..2 * p * 8).map(|i| i as f32 * 0.25).collect();
        let v: Vec<f32> = (0..2 * p * 8).map(|i| -(i as f32) * 0.5).collect();
        full.write_prefill(&k, &v, p);
        let n = 8;
        let payload = full.export_prefix(n).expect("pristine region exports");
        let pool = Arc::new(BlockPool::new(1 << 30));
        let idx = PrefixIndex::new(pool, 8);
        let geom = PrefixGeom { kind: "fp32", layers: 2, hkv: 1, dh: 8, prec_tag: 0 };
        let tokens: Vec<i32> = (0..n as i32).collect();
        let att = idx.publish(&tokens, geom, payload).expect("publish");

        let mut copied = mk();
        copied.attach_prefix(att.payload(), n).unwrap();
        copied.write_prefill_range(&k, &v, p, n, p);

        let mut aliased = mk();
        aliased.attach_prefix_alias(att.shared_arc(), n).unwrap();
        aliased.write_prefill_range(&k, &v, p, n, p);
        assert_eq!(aliased.shared_len(), n);
        assert_eq!(aliased.mask, copied.mask);
        assert_eq!(aliased.slot_pos, copied.slot_pos);
        aliased.check_invariants().unwrap();
        // rows readable through the alias, bit-equal to the copy
        let sh = aliased.shared_rows().expect("aliased rows advertised");
        assert_eq!((sh.len, sh.full_len), (n, n));
        let pr = &sh.k[(sh.full_len + 3) * 8..][..8]; // layer 1, slot 3
        let sr = &copied.k[(copied.capacity + 3) * 8..][..8];
        assert_eq!(pr, sr);
        // an aliased cache never exports
        assert!(aliased.export_prefix(n).is_none());
        // suspend-to-host overlays the payload: identical images
        assert_eq!(aliased.snapshot_state(), copied.snapshot_state());
        // copy-on-write: materialize then clear — full bit-identity
        aliased.materialize_shared();
        assert!(aliased.shared_rows().is_none());
        assert_eq!(aliased.k, copied.k);
        assert_eq!(aliased.v, copied.v);
        aliased.clear_shared();
        aliased.evict_positions(&[0, 1]);
        aliased.check_invariants().unwrap();
    }

    #[test]
    fn property_random_evict_flush_cycle() {
        prop::check(40, |g| {
            let mut c = Fp32Cache::new(2, 64, 4, 16);
            let p = g.usize(4, 32);
            let k = vec![0.5; 2 * p * 4];
            c.write_prefill(&k.clone(), &k, p);
            let mut pos = p;
            for _ in 0..g.usize(5, 40) {
                let full = c.push_token(&fake_out(2, 4, pos as f32), pos);
                pos += 1;
                if full {
                    while c.flush_buffer().is_err() {
                        let live = c.live_positions();
                        let n = (live.len() / 2).max(1);
                        c.evict_positions(&live[..n]);
                        if g.bool() {
                            c.compact_gather();
                        }
                    }
                }
                c.check_invariants()?;
            }
            Ok(())
        });
    }
}
