//! The Continuous-Thinking block table (paper §5.2, Figure 6).
//!
//! Per layer and per request: a list of allocated physical blocks with the
//! paper's extended fields. A *slot* is one token's KV position inside the
//! request's slab (`slot = phys_block * block_size + offset`).
//!
//! New-vs-PagedAttention fields (green in Figure 6):
//! * `thought`: the thought type of every token in the block — CT enforces
//!   **thought-aware paging** (a block only ever holds one thought type).
//! * `start_indices`: CoT start position of each segment stored in the block.
//! * `segment_mask`: per-slot index into `start_indices` (the paper's bit
//!   vectors, stored densely; `segment_bitmask()` renders the paper's view).
//! * `eviction_mask`: bit per slot, set by TBE soft-eviction, cleared when
//!   the slot is reused in place by a new token.

use super::Thought;

pub type SlotId = usize;

/// One physical block's CT metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockEntry {
    /// Physical block index inside the request slab.
    pub phys: usize,
    /// Number of slots ever filled (never decreases; reuse overwrites).
    pub filled: usize,
    /// Thought type of all tokens in this block (thought-aware paging).
    pub thought: Thought,
    /// Start position (CoT token index) of each segment present.
    pub start_indices: Vec<usize>,
    /// Per-slot: index into `start_indices` (-1 = never filled).
    pub segment_mask: Vec<i32>,
    /// Bit i set => slot i soft-evicted (reclaimable).
    pub eviction_mask: u64,
}

impl BlockEntry {
    fn new(phys: usize, block_size: usize, thought: Thought) -> BlockEntry {
        BlockEntry {
            phys,
            filled: 0,
            thought,
            start_indices: Vec::new(),
            segment_mask: vec![-1; block_size],
            eviction_mask: 0,
        }
    }

    pub fn is_evicted(&self, offset: usize) -> bool {
        self.eviction_mask & (1 << offset) != 0
    }

    /// The paper's per-start-index bit vector view of `segment_mask`.
    pub fn segment_bitmask(&self, start_index_pos: usize) -> u64 {
        let mut bits = 0u64;
        for (i, &seg) in self.segment_mask.iter().enumerate() {
            if seg == start_index_pos as i32 {
                bits |= 1 << i;
            }
        }
        bits
    }

    /// Live (filled, not evicted) slot count.
    pub fn live(&self) -> usize {
        (0..self.filled).filter(|&i| !self.is_evicted(i)).count()
    }
}

/// Where a token landed and whether it reclaimed an evicted slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub slot: SlotId,
    pub reused: bool,
}

/// Per-layer CT block table over a slab of `capacity` slots.
///
/// Derives `PartialEq` so suspend-to-host snapshots
/// ([`crate::kvcache::ct::CtSnapshot`]) can be compared bit-exactly in
/// round-trip tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerTable {
    pub block_size: usize,
    pub capacity: usize,
    pub blocks: Vec<BlockEntry>,
    free_blocks: Vec<usize>,
    /// Per-slot segment id (request-level segment numbering), -1 if dead.
    pub slot_segment: Vec<i32>,
    /// Per-slot CoT position, -1 if dead.
    pub slot_pos: Vec<i32>,
    /// Count of live slots.
    live: usize,
    /// Telemetry: in-place reuses vs fresh allocations (CT's win).
    pub reuse_count: u64,
    pub alloc_count: u64,
}

impl LayerTable {
    pub fn new(capacity: usize, block_size: usize) -> LayerTable {
        assert!(capacity % block_size == 0);
        assert!(block_size <= 64, "eviction mask is a u64 bit vector");
        LayerTable {
            block_size,
            capacity,
            blocks: Vec::new(),
            free_blocks: (0..capacity / block_size).rev().collect(),
            slot_segment: vec![-1; capacity],
            slot_pos: vec![-1; capacity],
            live: 0,
            reuse_count: 0,
            alloc_count: 0,
        }
    }

    pub fn live_slots(&self) -> usize {
        self.live
    }

    pub fn allocated_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks_left(&self) -> usize {
        self.free_blocks.len()
    }

    /// Place one token of `thought` / `segment` / CoT `pos`.
    ///
    /// CT policy (Figure 6 walkthrough):
    /// 1. reuse an eviction-marked slot in a block of the same thought type;
    /// 2. else append into a partially-filled block of the same thought type
    ///    (never into another thought's block — thought-aware paging);
    /// 3. else allocate a fresh physical block.
    /// Returns None when the slab is exhausted (caller must evict first).
    pub fn place(
        &mut self,
        thought: Thought,
        segment: usize,
        pos: usize,
    ) -> Option<Placement> {
        // (1) reclaim a soft-evicted slot of the same thought type
        for b in self.blocks.iter_mut() {
            if b.thought != thought || b.eviction_mask == 0 {
                continue;
            }
            let offset = (0..b.filled).find(|&i| b.is_evicted(i)).expect("mask non-empty");
            b.eviction_mask &= !(1 << offset);
            Self::note_segment(b, offset, segment, pos);
            let slot = b.phys * self.block_size + offset;
            self.slot_segment[slot] = segment as i32;
            self.slot_pos[slot] = pos as i32;
            self.live += 1;
            self.reuse_count += 1;
            return Some(Placement { slot, reused: true });
        }
        // (2) append into a same-thought block with room
        for b in self.blocks.iter_mut() {
            if b.thought != thought || b.filled >= self.block_size {
                continue;
            }
            let offset = b.filled;
            b.filled += 1;
            Self::note_segment(b, offset, segment, pos);
            let slot = b.phys * self.block_size + offset;
            self.slot_segment[slot] = segment as i32;
            self.slot_pos[slot] = pos as i32;
            self.live += 1;
            return Some(Placement { slot, reused: false });
        }
        // (2.5) recycle a fully-evicted block (possibly of another thought
        // type): every slot is reclaimable, so the block is reset wholesale.
        // Without this, thought-aware paging would strand dead blocks.
        if let Some(bi) = self
            .blocks
            .iter()
            .position(|b| b.filled > 0 && b.live() == 0)
        {
            let phys = self.blocks[bi].phys;
            let mut b = BlockEntry::new(phys, self.block_size, thought);
            b.filled = 1;
            Self::note_segment(&mut b, 0, segment, pos);
            self.blocks[bi] = b;
            let slot = phys * self.block_size;
            self.slot_segment[slot] = segment as i32;
            self.slot_pos[slot] = pos as i32;
            self.live += 1;
            self.reuse_count += 1;
            return Some(Placement { slot, reused: true });
        }
        // (3) allocate a fresh block
        let phys = self.free_blocks.pop()?;
        let mut b = BlockEntry::new(phys, self.block_size, thought);
        b.filled = 1;
        Self::note_segment(&mut b, 0, segment, pos);
        let slot = phys * self.block_size;
        self.blocks.push(b);
        self.slot_segment[slot] = segment as i32;
        self.slot_pos[slot] = pos as i32;
        self.live += 1;
        self.alloc_count += 1;
        Some(Placement { slot, reused: false })
    }

    fn note_segment(b: &mut BlockEntry, offset: usize, segment: usize, _pos: usize) {
        // `start_indices` records each segment that has tokens in this block
        // (keyed by the request-level segment id, whose start position the
        // segment store holds); `segment_mask` maps slots to that entry.
        let idx = match b.start_indices.iter().position(|&s| s == segment) {
            Some(i) => i,
            None => {
                b.start_indices.push(segment);
                b.start_indices.len() - 1
            }
        };
        b.segment_mask[offset] = idx as i32;
    }

    /// Soft-evict a slot (TBE): flips the eviction bit; the slot's payload
    /// stays in memory until a new token reuses it.
    pub fn soft_evict(&mut self, slot: SlotId) {
        let (bi, offset) = self.locate(slot).expect("slot is live");
        let b = &mut self.blocks[bi];
        assert!(!b.is_evicted(offset), "double eviction of slot {slot}");
        b.eviction_mask |= 1 << offset;
        self.slot_segment[slot] = -1;
        self.slot_pos[slot] = -1;
        self.live -= 1;
    }

    fn locate(&self, slot: SlotId) -> Option<(usize, usize)> {
        let phys = slot / self.block_size;
        let offset = slot % self.block_size;
        let bi = self.blocks.iter().position(|b| b.phys == phys)?;
        (offset < self.blocks[bi].filled).then_some((bi, offset))
    }

    /// Live slots of a given segment.
    pub fn segment_slots(&self, segment: usize) -> Vec<SlotId> {
        self.slot_segment
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == segment as i32)
            .map(|(i, _)| i)
            .collect()
    }

    /// All live slots.
    pub fn live_slot_ids(&self) -> Vec<SlotId> {
        self.slot_segment
            .iter()
            .enumerate()
            .filter(|(_, &s)| s >= 0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Internal-consistency check used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut live = 0;
        let mut seen_phys = std::collections::BTreeSet::new();
        for b in &self.blocks {
            if !seen_phys.insert(b.phys) {
                return Err(format!("duplicate phys block {}", b.phys));
            }
            if self.free_blocks.contains(&b.phys) {
                return Err(format!("block {} both allocated and free", b.phys));
            }
            if b.filled > self.block_size {
                return Err("overfilled block".into());
            }
            for i in 0..self.block_size {
                let slot = b.phys * self.block_size + i;
                let seg = self.slot_segment[slot];
                if i < b.filled && !b.is_evicted(i) {
                    if seg < 0 {
                        return Err(format!("live slot {slot} has no segment"));
                    }
                    if b.segment_mask[i] < 0 {
                        return Err(format!("live slot {slot} missing segment mask"));
                    }
                    live += 1;
                } else if seg >= 0 {
                    return Err(format!("dead slot {slot} has segment {seg}"));
                }
            }
            if b.eviction_mask >> b.filled != 0 {
                return Err("eviction bit beyond filled region".into());
            }
        }
        if live != self.live {
            return Err(format!("live count drift: counted {live}, cached {}", self.live));
        }
        // slots in unallocated blocks must be dead
        for &phys in &self.free_blocks {
            for i in 0..self.block_size {
                if self.slot_segment[phys * self.block_size + i] >= 0 {
                    return Err(format!("free block {phys} has live slot"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn place_fills_blocks_in_order() {
        let mut t = LayerTable::new(32, 8);
        for i in 0..8 {
            let p = t.place(Thought::Reasoning, 0, i).unwrap();
            assert!(!p.reused);
        }
        assert_eq!(t.allocated_blocks(), 1);
        t.place(Thought::Reasoning, 0, 8).unwrap();
        assert_eq!(t.allocated_blocks(), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn thought_aware_paging_never_mixes() {
        let mut t = LayerTable::new(64, 8);
        for i in 0..4 {
            t.place(Thought::Reasoning, 0, i).unwrap();
        }
        for i in 4..8 {
            t.place(Thought::Execution, 1, i).unwrap();
        }
        assert_eq!(t.allocated_blocks(), 2); // E must not join R's half-full block
        for b in &t.blocks {
            let slots: Vec<_> = (0..b.filled).collect();
            assert!(!slots.is_empty());
        }
        t.check_invariants().unwrap();
    }

    #[test]
    fn soft_evict_then_reuse_in_place() {
        let mut t = LayerTable::new(16, 8);
        let p0 = t.place(Thought::Transition, 0, 0).unwrap();
        let _p1 = t.place(Thought::Transition, 0, 1).unwrap();
        t.soft_evict(p0.slot);
        assert_eq!(t.live_slots(), 1);
        // same thought type reclaims the hole
        let p2 = t.place(Thought::Transition, 2, 100).unwrap();
        assert!(p2.reused);
        assert_eq!(p2.slot, p0.slot);
        assert_eq!(t.reuse_count, 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn other_thought_does_not_reclaim_partial_block() {
        let mut t = LayerTable::new(16, 8);
        let p0 = t.place(Thought::Transition, 0, 0).unwrap();
        let _p1 = t.place(Thought::Transition, 0, 1).unwrap(); // keeps block alive
        t.soft_evict(p0.slot);
        let p2 = t.place(Thought::Reasoning, 1, 2).unwrap();
        assert!(!p2.reused);
        assert_ne!(p2.slot / 8, p0.slot / 8); // landed in a different block
        t.check_invariants().unwrap();
    }

    #[test]
    fn fully_dead_block_is_recycled_across_thoughts() {
        let mut t = LayerTable::new(8, 8); // a single block
        let p0 = t.place(Thought::Transition, 0, 0).unwrap();
        t.soft_evict(p0.slot);
        // T block is fully dead; an R token may recycle it wholesale
        let p1 = t.place(Thought::Reasoning, 1, 1).unwrap();
        assert!(p1.reused);
        assert_eq!(t.blocks.len(), 1);
        assert_eq!(t.blocks[0].thought, Thought::Reasoning);
        t.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut t = LayerTable::new(16, 8);
        for i in 0..16 {
            assert!(t.place(Thought::Execution, 0, i).is_some());
        }
        assert!(t.place(Thought::Execution, 0, 99).is_none());
        // but eviction frees capacity
        t.soft_evict(3);
        assert!(t.place(Thought::Execution, 1, 99).is_some());
        t.check_invariants().unwrap();
    }

    #[test]
    fn segment_bitmask_matches_mask() {
        let mut t = LayerTable::new(16, 8);
        for i in 0..4 {
            t.place(Thought::Reasoning, 0, i).unwrap();
        }
        for i in 4..6 {
            t.place(Thought::Reasoning, 7, 128 + i).unwrap();
        }
        let b = &t.blocks[0];
        assert_eq!(b.start_indices.len(), 2);
        assert_eq!(b.segment_bitmask(0), 0b001111);
        assert_eq!(b.segment_bitmask(1), 0b110000);
    }

    #[test]
    fn property_random_ops_keep_invariants() {
        prop::check(60, |g| {
            let bs = *g.pick(&[4usize, 8, 16]);
            let cap = bs * g.usize(2, 8);
            let mut t = LayerTable::new(cap, bs);
            let mut live: Vec<SlotId> = Vec::new();
            let mut pos = 0usize;
            for step in 0..g.usize(20, 120) {
                if g.chance(0.7) {
                    let th = *g.pick(&Thought::ALL);
                    if let Some(p) = t.place(th, step / 10, pos) {
                        live.push(p.slot);
                        pos += 1;
                    }
                } else if !live.is_empty() {
                    let i = g.usize(0, live.len() - 1);
                    let slot = live.swap_remove(i);
                    t.soft_evict(slot);
                }
                t.check_invariants().map_err(|e| format!("step {step}: {e}"))?;
            }
            if t.live_slots() != live.len() {
                return Err("live count mismatch with model".into());
            }
            Ok(())
        });
    }
}
