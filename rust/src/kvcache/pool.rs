//! Global physical-block pool: device-memory accounting used to size the
//! maximum batch (paper Tables 2/3 report max batch per GPU) and to refuse
//! admission when KV memory is exhausted.
//!
//! The pool tracks *bytes*, not slots, because ThinKV requests with mixed
//! precision consume different amounts per token (packed accounting,
//! DESIGN §4).

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug)]
pub struct BlockPool {
    /// Total bytes available for KV cache on the (modeled) device.
    capacity_bytes: u64,
    used_bytes: AtomicU64,
    /// High-water mark for reporting.
    peak_bytes: AtomicU64,
}

impl BlockPool {
    pub fn new(capacity_bytes: u64) -> BlockPool {
        BlockPool {
            capacity_bytes,
            used_bytes: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn used(&self) -> u64 {
        self.used_bytes.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    pub fn free(&self) -> u64 {
        self.capacity_bytes.saturating_sub(self.used())
    }

    /// Try to reserve `bytes`; false if the pool would overflow.
    pub fn reserve(&self, bytes: u64) -> bool {
        let mut cur = self.used_bytes.load(Ordering::Relaxed);
        loop {
            let next = cur + bytes;
            if next > self.capacity_bytes {
                return false;
            }
            match self.used_bytes.compare_exchange_weak(
                cur,
                next,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak_bytes.fetch_max(next, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    pub fn release(&self, bytes: u64) {
        let prev = self.used_bytes.fetch_sub(bytes, Ordering::SeqCst);
        debug_assert!(prev >= bytes, "pool release underflow");
    }

    /// Max concurrent requests whose per-request KV footprint is `bytes`.
    pub fn max_batch(&self, bytes_per_request: u64) -> usize {
        if bytes_per_request == 0 {
            return usize::MAX;
        }
        (self.capacity_bytes / bytes_per_request) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn reserve_release() {
        let p = BlockPool::new(1000);
        assert!(p.reserve(600));
        assert!(!p.reserve(600));
        assert!(p.reserve(400));
        p.release(500);
        assert_eq!(p.used(), 500);
        assert_eq!(p.peak(), 1000);
    }

    #[test]
    fn max_batch_math() {
        let p = BlockPool::new(80 * 1024);
        assert_eq!(p.max_batch(1024), 80);
        assert_eq!(p.max_batch(0), usize::MAX);
    }

    #[test]
    fn concurrent_reservations_never_overflow() {
        let p = Arc::new(BlockPool::new(10_000));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if p.reserve(7) {
                        got += 7;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total <= 10_000);
        assert_eq!(p.used(), total);
    }
}
