//! Global physical-block pool: device-memory accounting used to size the
//! maximum batch (paper Tables 2/3 report max batch per GPU) and to refuse
//! admission when KV memory is exhausted.
//!
//! The pool tracks *bytes*, not slots, because ThinKV requests with mixed
//! precision consume different amounts per token (packed accounting,
//! DESIGN §4).
//!
//! # The byte ledger
//!
//! Every long-lived charge against a pool is a typed [`Lease`]
//! (admission grants, growth bonds, CoW reservations, prefix residency,
//! swap snapshots). A lease is `#[must_use]` and **debug-panics if
//! dropped without being settled or transferred** — forgetting to
//! return bytes becomes a test failure instead of a slow capacity leak.
//! Each pool keeps a [`LeaseLedger`] (live lease count + leased bytes),
//! and [`BlockPool::audit`] exposes the conservation check
//! `pool.used == Σ live-lease bytes` that the integration suites assert
//! at scheduler quiescent points.
//!
//! The raw [`BlockPool::reserve`]/[`BlockPool::release`] pair remains
//! as the *unledgered* escape hatch (tests and benches that deliberately
//! drain a pool, transient probes). Raw charges are invisible to the
//! ledger, so [`BlockPool::assert_conserved`] is only meaningful at
//! points where no raw charge is outstanding.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Live-lease accounting for one pool: how many [`Lease`]s exist and
/// how many bytes they hold. Maintained by the lease lifecycle, read by
/// [`BlockPool::audit`].
#[derive(Debug, Default)]
pub struct LeaseLedger {
    live: AtomicU64,
    bytes: AtomicU64,
}

impl LeaseLedger {
    /// Number of live leases.
    pub fn live(&self) -> u64 {
        self.live.load(Ordering::SeqCst)
    }

    /// Total bytes held by live leases.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::SeqCst)
    }
}

/// Point-in-time conservation snapshot of one pool; see
/// [`BlockPool::audit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolAudit {
    /// Bytes the pool itself counts as in use.
    pub used: u64,
    /// Bytes held by live leases.
    pub leased: u64,
    /// Number of live leases.
    pub live: u64,
}

impl PoolAudit {
    /// True when every used byte is explained by a live lease.
    pub fn conserved(&self) -> bool {
        self.used == self.leased
    }
}

/// A pool a [`Lease`] can charge: byte reserve/release plus the ledger
/// the lease lifecycle maintains. Implemented by [`BlockPool`] (device
/// KV bytes) and [`SwapPool`](super::SwapPool) (host snapshot bytes).
pub trait PoolLike: Send + Sync {
    /// Try to take `bytes` from the pool; false if it would overflow.
    fn try_reserve_raw(&self, bytes: u64) -> bool;
    /// Return `bytes` to the pool.
    fn release_raw(&self, bytes: u64);
    /// The pool's lease ledger.
    fn ledger(&self) -> &LeaseLedger;
    /// Diagnostic name, printed when a lease leaks.
    fn pool_name(&self) -> &'static str;
}

/// An owned, typed charge of `bytes` against a pool.
///
/// Created by [`Lease::charge`] (or the pools' `lease()` conveniences),
/// resized with [`grow`](Lease::grow)/[`shrink`](Lease::shrink), moved
/// between owners with [`merge`](Lease::merge), and returned to the
/// pool with [`settle`](Lease::settle). Dropping a lease any other way
/// self-heals (the bytes are released and the ledger stays consistent)
/// and then **panics in debug builds** — an unsettled drop is a byte
/// leak in the accounting model even though the pool recovers.
#[must_use = "a Lease is owned pool capacity: settle(), merge, or store it"]
#[derive(Debug)]
pub struct Lease<P: PoolLike> {
    pool: Arc<P>,
    bytes: u64,
    settled: bool,
}

/// A lease of device KV bytes against a [`BlockPool`].
pub type ByteLease = Lease<BlockPool>;

impl<P: PoolLike> Lease<P> {
    /// Charge `bytes` against `pool`; `None` if the pool is full.
    /// A zero-byte lease always succeeds (an empty-but-armed charge:
    /// sessions park one while holding no bytes).
    pub fn charge(pool: &Arc<P>, bytes: u64) -> Option<Lease<P>> {
        if !pool.try_reserve_raw(bytes) {
            return None;
        }
        let ledger = pool.ledger();
        ledger.live.fetch_add(1, Ordering::SeqCst);
        ledger.bytes.fetch_add(bytes, Ordering::SeqCst);
        Some(Lease { pool: Arc::clone(pool), bytes, settled: false })
    }

    /// Bytes this lease currently holds.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The pool this lease charges.
    pub fn pool(&self) -> &Arc<P> {
        &self.pool
    }

    /// Enlarge the lease by `delta` bytes; false (lease unchanged) if
    /// the pool cannot cover it.
    pub fn grow(&mut self, delta: u64) -> bool {
        if !self.pool.try_reserve_raw(delta) {
            return false;
        }
        self.pool.ledger().bytes.fetch_add(delta, Ordering::SeqCst);
        self.bytes += delta;
        true
    }

    /// Return `delta` of this lease's bytes to the pool early.
    pub fn shrink(&mut self, delta: u64) {
        debug_assert!(delta <= self.bytes, "lease shrink below zero");
        let delta = delta.min(self.bytes);
        self.pool.release_raw(delta);
        self.pool.ledger().bytes.fetch_sub(delta, Ordering::SeqCst);
        self.bytes -= delta;
    }

    /// Absorb `other` into this lease (ownership transfer, e.g. a CoW
    /// reservation draining into its session's admission lease). Both
    /// leases must charge the same pool.
    pub fn merge(&mut self, other: Lease<P>) {
        debug_assert!(
            Arc::ptr_eq(&self.pool, &other.pool),
            "merging leases across pools ({} vs {})",
            self.pool.pool_name(),
            other.pool.pool_name()
        );
        let mut other = other;
        self.bytes += other.bytes;
        // disarm: its Drop then only retires the ledger's live count —
        // the bytes now live here, so neither pool nor ledger changes
        other.bytes = 0;
        other.settled = true;
    }

    /// Return every byte to the pool and retire the lease.
    pub fn settle(mut self) {
        self.settled = true;
        // Drop performs the release
    }
}

impl<P: PoolLike> Drop for Lease<P> {
    fn drop(&mut self) {
        // always self-heal first so the ledger and pool stay consistent
        // even when the leak panic below unwinds (or is caught)
        self.pool.release_raw(self.bytes);
        let ledger = self.pool.ledger();
        ledger.bytes.fetch_sub(self.bytes, Ordering::SeqCst);
        ledger.live.fetch_sub(1, Ordering::SeqCst);
        if !self.settled && cfg!(debug_assertions) && !std::thread::panicking() {
            panic!(
                "leaked lease: {} bytes against pool `{}` dropped without \
                 settle()/merge() — a charge path lost track of its bytes",
                self.bytes,
                self.pool.pool_name()
            );
        }
    }
}

#[derive(Debug)]
pub struct BlockPool {
    /// Total bytes available for KV cache on the (modeled) device.
    capacity_bytes: u64,
    used_bytes: AtomicU64,
    /// High-water mark for reporting.
    peak_bytes: AtomicU64,
    ledger: LeaseLedger,
}

impl BlockPool {
    pub fn new(capacity_bytes: u64) -> BlockPool {
        BlockPool {
            capacity_bytes,
            used_bytes: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
            ledger: LeaseLedger::default(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn used(&self) -> u64 {
        self.used_bytes.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    pub fn free(&self) -> u64 {
        self.capacity_bytes.saturating_sub(self.used())
    }

    /// Try to reserve `bytes`; false if the pool would overflow.
    ///
    /// This is the **unledgered** escape hatch: the charge is invisible
    /// to [`BlockPool::audit`]. Long-lived charges should go through
    /// [`BlockPool::lease`] instead.
    #[must_use = "a failed reserve means the bytes were NOT taken"]
    pub fn reserve(&self, bytes: u64) -> bool {
        let mut cur = self.used_bytes.load(Ordering::Relaxed);
        loop {
            let next = cur + bytes;
            if next > self.capacity_bytes {
                return false;
            }
            match self.used_bytes.compare_exchange_weak(
                cur,
                next,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak_bytes.fetch_max(next, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    pub fn release(&self, bytes: u64) {
        let prev = self.used_bytes.fetch_sub(bytes, Ordering::SeqCst);
        debug_assert!(prev >= bytes, "pool release underflow");
    }

    /// Charge `bytes` as a ledgered [`ByteLease`]; `None` if full.
    pub fn lease(self: &Arc<Self>, bytes: u64) -> Option<ByteLease> {
        Lease::charge(self, bytes)
    }

    /// Conservation snapshot: pool-counted bytes vs ledgered leases.
    pub fn audit(&self) -> PoolAudit {
        PoolAudit {
            used: self.used(),
            leased: self.ledger.bytes(),
            live: self.ledger.live(),
        }
    }

    /// Assert `pool.used == Σ live-lease bytes`. Call only at quiescent
    /// points with no raw (unledgered) charge outstanding.
    #[track_caller]
    pub fn assert_conserved(&self) {
        let a = self.audit();
        assert!(
            a.conserved(),
            "pool byte-conservation violated: used={} but leases hold {} across {} leases",
            a.used,
            a.leased,
            a.live
        );
    }

    /// Max concurrent requests whose per-request KV footprint is `bytes`.
    pub fn max_batch(&self, bytes_per_request: u64) -> usize {
        if bytes_per_request == 0 {
            return usize::MAX;
        }
        (self.capacity_bytes / bytes_per_request) as usize
    }
}

impl PoolLike for BlockPool {
    fn try_reserve_raw(&self, bytes: u64) -> bool {
        self.reserve(bytes)
    }

    fn release_raw(&self, bytes: u64) {
        self.release(bytes);
    }

    fn ledger(&self) -> &LeaseLedger {
        &self.ledger
    }

    fn pool_name(&self) -> &'static str {
        "kv"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn reserve_release() {
        let p = BlockPool::new(1000);
        assert!(p.reserve(600));
        assert!(!p.reserve(600));
        assert!(p.reserve(400));
        p.release(500);
        assert_eq!(p.used(), 500);
        assert_eq!(p.peak(), 1000);
    }

    #[test]
    fn max_batch_math() {
        let p = BlockPool::new(80 * 1024);
        assert_eq!(p.max_batch(1024), 80);
        assert_eq!(p.max_batch(0), usize::MAX);
    }

    #[test]
    fn concurrent_reservations_never_overflow() {
        let p = Arc::new(BlockPool::new(10_000));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if p.reserve(7) {
                        got += 7;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total <= 10_000);
        assert_eq!(p.used(), total);
    }

    #[test]
    fn lease_lifecycle_conserves_bytes() {
        let p = Arc::new(BlockPool::new(1000));
        let mut a = p.lease(300).expect("fits");
        let b = p.lease(200).expect("fits");
        assert_eq!(p.used(), 500);
        p.assert_conserved();
        assert!(a.grow(100));
        assert_eq!(a.bytes(), 400);
        a.shrink(50);
        assert_eq!(p.used(), 550);
        p.assert_conserved();
        a.merge(b);
        assert_eq!(a.bytes(), 550);
        let audit = p.audit();
        assert_eq!(audit.live, 1, "merge retires the absorbed lease");
        p.assert_conserved();
        a.settle();
        assert_eq!(p.used(), 0);
        assert_eq!(p.audit().live, 0);
        p.assert_conserved();
    }

    #[test]
    fn lease_charge_fails_closed_when_full() {
        let p = Arc::new(BlockPool::new(100));
        let l = p.lease(80).expect("fits");
        assert!(p.lease(30).is_none(), "over-capacity lease must fail");
        assert_eq!(p.used(), 80, "failed charge leaves no residue");
        p.assert_conserved();
        let mut l = l;
        assert!(!l.grow(30), "over-capacity grow must fail");
        assert_eq!(l.bytes(), 80);
        l.settle();
        p.assert_conserved();
    }

    #[test]
    fn zero_byte_lease_is_legal() {
        let p = Arc::new(BlockPool::new(10));
        let mut l = p.lease(0).expect("zero-byte lease always fits");
        assert!(l.grow(10));
        l.shrink(10);
        l.settle();
        p.assert_conserved();
    }

    /// Seeded violation: the leak detector is itself regression-tested.
    #[cfg(debug_assertions)]
    #[test]
    fn leaked_lease_panics_and_self_heals() {
        let p = Arc::new(BlockPool::new(1000));
        let err = std::panic::catch_unwind({
            let p = Arc::clone(&p);
            move || {
                let _leak = p.lease(123).expect("fits");
                // dropped here without settle(): the detector fires
            }
        })
        .expect_err("an unsettled drop must panic in debug builds");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("leaked lease"), "got: {msg}");
        assert!(msg.contains("123"), "got: {msg}");
        // the drop self-healed before panicking: no residue, ledger
        // consistent, pool still fully usable
        assert_eq!(p.used(), 0);
        assert_eq!(p.audit().live, 0);
        p.assert_conserved();
    }

    #[test]
    fn conservation_check_catches_raw_imbalance() {
        let p = Arc::new(BlockPool::new(1000));
        assert!(p.reserve(10)); // raw charge: invisible to the ledger
        assert!(!p.audit().conserved());
        let err = std::panic::catch_unwind({
            let p = Arc::clone(&p);
            move || p.assert_conserved()
        });
        assert!(err.is_err(), "raw imbalance must fail the audit");
        p.release(10);
        p.assert_conserved();
    }
}
