//! Repo lint driver: `cargo run -p xtask -- lint` (or `make xtask-lint`).
//!
//! Three surfaces describe the `SchedSnapshot` counter set and drift
//! independently under review pressure:
//!
//! 1. the code itself — the `.set("…")` calls in
//!    `SchedSnapshot::to_json` (`rust/src/metrics/mod.rs`);
//! 2. the counter map — the table under "## Where each SchedSnapshot
//!    counter is incremented" in `docs/ARCHITECTURE.md`, whose first
//!    cell names counters in backticks (slash- or comma-grouped, with
//!    `pool_*`-style wildcard rows);
//! 3. the README stats ledger — the `{"cmd": "stats"}` bullet listing
//!    every key a server `stats` reply carries.
//!
//! `lint` parses all three and fails on drift in *either* direction: a
//! JSON key no doc mentions, or a doc entry naming a key the code no
//! longer emits. Backticked identifiers in the README bullet that are
//! not top-level keys must be on the small per-class/server-field
//! allowlist ([`README_EXTRA`]). The parsers are deliberately dumb
//! (substring scans, no regex, no deps) and each refuses to pass when
//! its anchor text vanishes — moving a surface breaks the lint loudly
//! instead of silently scanning nothing.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Heading the ARCHITECTURE.md counter-map parser anchors on.
const ARCH_HEADING: &str = "## Where each SchedSnapshot counter is incremented";

/// Backticked identifiers the README stats bullet may use that are not
/// top-level `SchedSnapshot` JSON keys: fields of the per-class
/// `slo_classes` scoreboards plus the two keys the *server* adds to
/// the reply.
const README_EXTRA: &[&str] = &[
    "served",
    "mode",
    "name",
    "violations",
    "ttft_p50",
    "ttft_p99",
    "tpot_p50_milli",
    "tpot_p99_milli",
];

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn read(path: &Path) -> String {
    match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("xtask: cannot read {}: {e}", path.display());
            std::process::exit(2);
        }
    }
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => lint(),
        other => {
            eprintln!(
                "usage: cargo run -p xtask -- lint{}",
                other.map_or(String::new(), |c| format!(" (unknown command `{c}`)"))
            );
            ExitCode::FAILURE
        }
    }
}

fn lint() -> ExitCode {
    let root = repo_root();
    let metrics = read(&root.join("rust/src/metrics/mod.rs"));
    let arch = read(&root.join("docs/ARCHITECTURE.md"));
    let readme = read(&root.join("README.md"));
    let errs = run_lint(&metrics, &arch, &readme);
    if errs.is_empty() {
        let n = snapshot_keys(&metrics).len();
        println!("xtask lint: {n} SchedSnapshot keys consistent across code and docs");
        ExitCode::SUCCESS
    } else {
        for e in &errs {
            eprintln!("xtask lint: {e}");
        }
        eprintln!("xtask lint: {} drift error(s)", errs.len());
        ExitCode::FAILURE
    }
}

/// All drift errors across the three surfaces (empty = consistent).
fn run_lint(metrics: &str, arch: &str, readme: &str) -> Vec<String> {
    let keys = snapshot_keys(metrics);
    let (arch_exact, arch_wild) = arch_counters(arch);
    let readme_keys = readme_counters(readme);
    let mut errs = Vec::new();

    // Anchor guards: an empty parse means the surface moved, not that
    // there is nothing to check.
    if keys.is_empty() {
        errs.push("no `.set` keys under `impl SchedSnapshot` — did to_json move?".into());
    }
    if arch_exact.is_empty() && arch_wild.is_empty() {
        errs.push(format!("no counter-map rows under \"{ARCH_HEADING}\" — table moved?"));
    }
    if readme_keys.is_empty() {
        errs.push("no keys in the README `{\"cmd\": \"stats\"}` bullet — did it move?".into());
    }
    if !errs.is_empty() {
        return errs;
    }

    let covered = |k: &str| {
        arch_exact.iter().any(|a| a == k) || arch_wild.iter().any(|w| k.starts_with(w.as_str()))
    };
    for k in &keys {
        if !covered(k) {
            errs.push(format!(
                "SchedSnapshot emits `{k}` but the ARCHITECTURE.md counter map has no row for it"
            ));
        }
        if !readme_keys.iter().any(|r| r == k) {
            errs.push(format!(
                "SchedSnapshot emits `{k}` but the README stats ledger does not document it"
            ));
        }
    }
    for a in &arch_exact {
        if !keys.iter().any(|k| k == a) {
            errs.push(format!(
                "ARCHITECTURE.md lists `{a}` but SchedSnapshot::to_json emits no such key"
            ));
        }
    }
    for r in &readme_keys {
        if !keys.iter().any(|k| k == r) && !README_EXTRA.contains(&r.as_str()) {
            errs.push(format!(
                "README stats ledger mentions `{r}`: not a SchedSnapshot key or known field"
            ));
        }
    }
    errs
}

/// JSON keys emitted by `SchedSnapshot::to_json`: the first string
/// literal after every `.set(` between `impl SchedSnapshot` and the
/// next top-level `impl` (rustfmt may put the key on its own line, so
/// the scan skips whitespace before the opening quote).
fn snapshot_keys(src: &str) -> Vec<String> {
    let Some(start) = src.find("impl SchedSnapshot") else {
        return Vec::new();
    };
    let body = &src[start..];
    let end = body[1..].find("\nimpl ").map_or(body.len(), |i| i + 1);
    let mut rest = &body[..end];
    let mut keys = Vec::new();
    while let Some(i) = rest.find(".set(") {
        rest = &rest[i + ".set(".len()..];
        if let Some(lit) = rest.trim_start().strip_prefix('"') {
            if let Some(q) = lit.find('"') {
                keys.push(lit[..q].to_string());
            }
        }
    }
    keys
}

/// Counter names from the ARCHITECTURE.md map: `(exact, wildcard
/// prefixes)`. Rows group related counters with ` / ` or `, `; a name
/// ending in `*` (e.g. `pool_*`) covers every key with that prefix.
fn arch_counters(doc: &str) -> (Vec<String>, Vec<String>) {
    let Some(start) = doc.find(ARCH_HEADING) else {
        return (Vec::new(), Vec::new());
    };
    let (mut exact, mut wild) = (Vec::new(), Vec::new());
    for line in doc[start..].lines().skip(1) {
        if line.starts_with("## ") {
            break;
        }
        let Some(row) = line.strip_prefix('|') else {
            continue;
        };
        let Some(cell) = row.split('|').next() else {
            continue;
        };
        for tok in backticked(cell) {
            if let Some(prefix) = tok.strip_suffix('*') {
                wild.push(prefix.to_string());
            } else if is_key_ident(&tok) {
                exact.push(tok);
            }
        }
    }
    (exact, wild)
}

/// Backticked key-like identifiers in the README stats bullet: from
/// the start of the line holding the `{"cmd": "stats"}` marker to the
/// start of the line holding `{"cmd": "shutdown"}` (both markers sit
/// inside backtick spans, so the region must begin at a line boundary
/// to keep backtick parity right).
fn readme_counters(readme: &str) -> Vec<String> {
    let Some(hit) = readme.find(r#"{"cmd": "stats"}"#) else {
        return Vec::new();
    };
    let start = readme[..hit].rfind('\n').map_or(0, |i| i + 1);
    let region = &readme[start..];
    let end = region
        .find(r#"{"cmd": "shutdown"}"#)
        .map_or(region.len(), |i| region[..i].rfind('\n').map_or(region.len(), |j| j + 1));
    let mut out: Vec<String> = backticked(&region[..end])
        .into_iter()
        .filter(|t| is_key_ident(t))
        .collect();
    out.dedup();
    out
}

/// Contents of every `` `…` `` span, in order.
fn backticked(s: &str) -> Vec<String> {
    s.split('`')
        .enumerate()
        .filter_map(|(i, seg)| (i % 2 == 1).then(|| seg.to_string()))
        .collect()
}

/// True for snake_case counter names: lowercase-letter head, then
/// lowercase alphanumerics and underscores. Rejects prose, flags
/// (`--idle-swap-ticks`), and quoted values (`"goodput"`).
fn is_key_ident(s: &str) -> bool {
    let mut chars = s.chars();
    chars.next().is_some_and(|c| c.is_ascii_lowercase())
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

#[cfg(test)]
mod tests {
    use super::*;

    const METRICS_FIXTURE: &str = r#"
impl SloClassSnap {
    pub fn to_json(&self) -> Json {
        j.set("name", Json::Str(self.name.clone()));
    }
}
impl SchedSnapshot {
    pub fn to_json(&self) -> Json {
        j.set("pool_used", Json::Num(self.pool_used as f64));
        j.set("pool_leases", Json::Num(self.pool_leases as f64));
        j.set(
            "batch_hist",
            Json::Arr(Vec::new()),
        );
        j.set("admissions", Json::Num(self.admissions as f64));
        j
    }
}
"#;

    const ARCH_FIXTURE: &str = "\
## Where each SchedSnapshot counter is incremented

| Counter | Incremented in |
|---|---|
| `admissions` | `Scheduler::try_admit` |
| `pool_*` | read from the `BlockPool` |
| `batch_hist` | `Scheduler::note_fused_step` |

## Threading model
";

    const README_FIXTURE: &str = "\
Control lines:

* `{\"cmd\": \"stats\"}` → counters: `pool_used`, `pool_leases`,
  `admissions`, `batch_hist` (per-class: `name`, `ttft_p50`), the
  `--idle-swap-ticks` flag and `\"goodput\"` mode, plus `served`.
* `{\"cmd\": \"shutdown\"}` → `{\"ok\": true}`.
";

    #[test]
    fn snapshot_keys_scan_handles_multiline_set_and_scopes_to_impl() {
        let keys = snapshot_keys(METRICS_FIXTURE);
        assert_eq!(keys, ["pool_used", "pool_leases", "batch_hist", "admissions"]);
        assert!(!keys.contains(&"name".to_string()), "SloClassSnap keys must not leak in");
    }

    #[test]
    fn arch_parser_splits_groups_and_wildcards() {
        let (exact, wild) = arch_counters(ARCH_FIXTURE);
        assert_eq!(exact, ["admissions", "batch_hist"]);
        assert_eq!(wild, ["pool_"]);
    }

    #[test]
    fn readme_parser_keeps_keys_and_drops_flags_and_quoted_values() {
        let keys = readme_counters(README_FIXTURE);
        assert_eq!(
            keys,
            ["pool_used", "pool_leases", "admissions", "batch_hist", "name", "ttft_p50", "served"]
        );
    }

    #[test]
    fn consistent_fixture_passes() {
        let errs = run_lint(METRICS_FIXTURE, ARCH_FIXTURE, README_FIXTURE);
        assert!(errs.is_empty(), "unexpected drift: {errs:?}");
    }

    #[test]
    fn seeded_new_key_without_docs_is_caught_in_both_directions() {
        let drifted = METRICS_FIXTURE.replace(
            "j.set(\"admissions\"",
            "j.set(\"bogus_key\", Json::Num(0.0));\n        j.set(\"admissions\"",
        );
        let errs = run_lint(&drifted, ARCH_FIXTURE, README_FIXTURE);
        assert!(
            errs.iter().any(|e| e.contains("`bogus_key`") && e.contains("counter map")),
            "ARCH-side drift not caught: {errs:?}"
        );
        assert!(
            errs.iter().any(|e| e.contains("`bogus_key`") && e.contains("stats ledger")),
            "README-side drift not caught: {errs:?}"
        );
    }

    #[test]
    fn seeded_stale_arch_row_is_caught() {
        let drifted = ARCH_FIXTURE.replace("`batch_hist`", "`batch_hist`, `removed_counter`");
        let errs = run_lint(METRICS_FIXTURE, &drifted, README_FIXTURE);
        assert!(
            errs.iter().any(|e| e.contains("`removed_counter`") && e.contains("no such key")),
            "stale ARCH entry not caught: {errs:?}"
        );
    }

    #[test]
    fn seeded_unknown_readme_mention_is_caught() {
        let drifted = README_FIXTURE.replace("`served`", "`served`, `mystery_key`");
        let errs = run_lint(METRICS_FIXTURE, ARCH_FIXTURE, &drifted);
        assert!(
            errs.iter().any(|e| e.contains("`mystery_key`")),
            "unknown README mention not caught: {errs:?}"
        );
    }

    #[test]
    fn missing_anchors_fail_instead_of_passing_vacuously() {
        let errs = run_lint("fn main() {}", "# nothing", "# nothing");
        assert_eq!(errs.len(), 3, "every vanished surface must error: {errs:?}");
    }

    #[test]
    fn real_repo_surfaces_are_consistent() {
        let root = repo_root();
        let metrics = std::fs::read_to_string(root.join("rust/src/metrics/mod.rs")).unwrap();
        let arch = std::fs::read_to_string(root.join("docs/ARCHITECTURE.md")).unwrap();
        let readme = std::fs::read_to_string(root.join("README.md")).unwrap();
        let errs = run_lint(&metrics, &arch, &readme);
        assert!(errs.is_empty(), "live drift between code and docs: {errs:?}");
    }
}
