//! Figure 11: (a) |L*|, |T| and min-retention ablations; (b) RxEyTz
//! precision-assignment sweep.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::compress::tbq::PrecisionAssignment;
use thinkv::sim::harness::{Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, Trace};

fn run(ds: &DatasetProfile, tk: ThinKvSim, budget: usize, scale: f64) -> (f64, f64) {
    let seeds = bench_seeds();
    let (mut acc, mut bits) = (0.0, 0.0);
    for &s in &seeds {
        let trace = Trace::generate(ds, s, scale);
        let r = run_method(&trace, &Method::ThinKv(tk.clone()), &SimConfig { budget, seed: s, stride: 4, rollouts: 24 });
        acc += r.pass1;
        bits += r.avg_bits;
    }
    (acc / seeds.len() as f64, bits / seeds.len() as f64)
}

fn main() {
    let scale = bench_len_scale();
    let lcb = DatasetProfile::livecodebench();

    // (a) |T| sweep: 1 (LLM mode), 2, 3
    let mut ta = Table::new("Fig 11(a): # thought types |T| (LCB, k=1024)", &["n_thoughts", "pass@1"]);
    for n in [1usize, 2, 3] {
        let tk = ThinKvSim {
            n_thoughts: n,
            thresholds: thinkv::thought::calibration::default_thresholds(n),
            ..Default::default()
        };
        let (acc, _) = run(&lcb, tk, 1024, scale);
        ta.row(&[format!("{n}"), format!("{:.3}", acc)]);
    }
    ta.print();

    // (a) min retention sweep
    let mut tm = Table::new("Fig 11(a): min retention (LCB, k=512)", &["min_R", "pass@1"]);
    for min_r in [0usize, 1, 4, 8, 16] {
        let mut retention = vec![64, 32, 16, 8];
        retention.push(min_r);
        let tk = ThinKvSim { retention, min_keep: min_r, ..Default::default() };
        let (acc, _) = run(&lcb, tk, 512, scale);
        tm.row(&[format!("{min_r}"), format!("{:.3}", acc)]);
    }
    tm.print();

    // (a) |L*|: noisy thresholds emulate selecting non-trimodal layers
    let mut tl = Table::new("Fig 11(a): |L*| layer-subset quality (LCB, k=1024)", &["layers", "threshold_noise", "pass@1"]);
    for (l, noise) in [(1usize, 0.10), (2, 0.05), (4, 0.0), (8, 0.04), (32, 0.12)] {
        let tk = ThinKvSim {
            thresholds: vec![0.42 + noise, 0.7 - noise],
            ..Default::default()
        };
        let (acc, _) = run(&lcb, tk, 1024, scale);
        tl.row(&[format!("{l}"), format!("{:.2}", noise), format!("{:.3}", acc)]);
    }
    tl.print();

    // (b) RxEyTz sweep
    let mut tb = Table::new(
        "Fig 11(b): precision assignment RxEyTz (AIME + LCB, k=1024)",
        &["assignment", "AIME", "LCB", "avg_bits"],
    );
    let aime = DatasetProfile::aime();
    for name in ["R8E8T8", "R8E4T2", "R4E4T2", "R4E2T2", "R2E2T2"] {
        let a = PrecisionAssignment::parse(name).unwrap();
        let tk = ThinKvSim { assignment: a, ..Default::default() };
        let (acc_a, bits) = run(&aime, tk.clone(), 1024, scale);
        let (acc_l, _) = run(&lcb, tk, 1024, scale);
        tb.row(&[name.into(), format!("{:.3}", acc_a), format!("{:.3}", acc_l), format!("{:.1}", bits)]);
    }
    tb.print();

    let mut j = ta.to_json();
    j.set("min_retention", tm.to_json());
    j.set("layers", tl.to_json());
    j.set("precision", tb.to_json());
    write_results("fig11_ablations", j);
    println!("\nExpected shapes: |T|=3 best; minR=0 collapses (loops), minR=4 optimal;\ncalibrated L* beats noisy thresholds; R4E4T2 matches R8E4T2 accuracy at\nhigher compression; R2E2T2 degrades.");
}
