//! Figure 9: multi-user system throughput vs per-user latency (vLLM-style
//! dynamic serving analysis) — cost-model sweep plus a real coordinator
//! mini-run at laptop scale.

use thinkv::bench::{write_results, Table};
use thinkv::sim::{GpuProfile, LrmProfile, ServingCost};

fn main() {
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b());
    let gen = 9020.0; // AIME mean generation
    let mut t = Table::new(
        "Figure 9: reqs/s vs user latency (R1-Llama-8B profile, AIME, k=1024)",
        &["method", "batch", "reqs_per_s", "user_latency_s", "note"],
    );
    for batch in [1usize, 8, 32, 64, 128, 256] {
        // FullKV: cache grows with generation; max batch ~13
        if batch <= 13 {
            let kv = cost.model.fullkv_bytes_per_token() * gen / 2.0;
            let step = cost.decode_step(batch, kv, 0.0, false, 0.0);
            let lat = step.total_us() * gen / 1e6;
            t.row(&["FullKV".into(), format!("{batch}"), format!("{:.3}", batch as f64 / lat), format!("{:.0}", lat), "".into()]);
        }
        // R-KV: 1024 fp16 + gather every step
        let kv_rkv = cost.model.kv_bytes_per_token(16.0) * 1024.0;
        let step = cost.decode_step(batch, kv_rkv, kv_rkv * 0.05, true, 0.0);
        let lat = step.total_us() * gen / 1e6;
        t.row(&["R-KV (ovl)".into(), format!("{batch}"), format!("{:.3}", batch as f64 / lat), format!("{:.0}", lat), "".into()]);
        // ThinKV: 1024 @ 3.4 bits, no gather, ~5% steps with TBE overhead
        let kv_tk = cost.model.kv_bytes_per_token(3.4) * 1024.0;
        let step = cost.decode_step(batch, kv_tk, 0.0, false, 2.0);
        let lat = step.total_us() * gen / 1e6;
        t.row(&["ThinKV".into(), format!("{batch}"), format!("{:.3}", batch as f64 / lat), format!("{:.0}", lat), "".into()]);
    }
    t.print();

    // real coordinator mini-run (CPU PJRT, tiny model)
    if std::path::Path::new(&format!("{}/model_config.json", thinkv::model::default_artifacts_dir())).exists()
        && std::env::var("THINKV_BENCH_REAL").map(|v| v == "1").unwrap_or(true)
    {
        use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
        let mut t2 = Table::new(
            "Real coordinator mini-run (CPU PJRT, 32 tokens/request)",
            &["mode", "users", "reqs_per_s", "mean_latency_ms"],
        );
        for (mode, label) in [
            (CompressionMode::thinkv_default(), "ThinKV"),
            (CompressionMode::FullKv, "FullKV"),
        ] {
            for users in [1usize, 4] {
                let cfg = ServeConfig {
                    mode: mode.clone(),
                    budget: 256,
                    max_new_tokens: 32,
                    workers: 2,
                    ..ServeConfig::default()
                };
                let c = Coordinator::start(cfg).unwrap();
                let prompts: Vec<Vec<i32>> = (0..users)
                    .map(|u| (0..64).map(|i| ((i * 3 + u) % 512) as i32).collect())
                    .collect();
                // warmup compile
                let _ = c.run_batch(vec![prompts[0].clone()]);
                let t0 = std::time::Instant::now();
                let rs = c.run_batch(prompts).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                let mean_lat: f64 = rs.iter().map(|r| r.total_ms).sum::<f64>() / rs.len() as f64;
                t2.row(&[label.into(), format!("{users}"), format!("{:.2}", users as f64 / wall), format!("{:.0}", mean_lat)]);
            }
        }
        t2.print();
        write_results("fig9_serving_real", t2.to_json());
    }
    write_results("fig9_serving", t.to_json());
    println!("\nExpected shape (paper): FullKV saturates at B<=13; at iso-batch ThinKV gives\n~58% lower latency vs FullKV@8 and higher reqs/s + lower latency than R-KV at\nB=256 (no gather, smaller cache reads).");
}
