//! Microbenchmarks of the real hot paths (perf-pass instrumentation):
//! decode-step execution (quant vs fp32 HLO), standalone fused attention,
//! Rust-side group quantization, k-means eviction, gather compaction.

use thinkv::bench::{time_ms, write_results, Table};
use thinkv::compress::kmeans_select;
use thinkv::kvcache::Fp32Cache;
use thinkv::quant::{quant_groups, Precision};
use thinkv::runtime::{Engine, QuantCache};
use thinkv::util::rng::Rng;

fn main() {
    let mut t = Table::new("Microbenchmarks (real CPU timings)", &["op", "config", "mean_ms", "best_ms"]);

    // rust group quantization (cache-write path)
    let mut rng = Rng::new(1);
    let mut x = vec![0f32; 64];
    rng.fill_normal_f32(&mut x, 0.0, 1.0);
    let mut codes = vec![0u8; 64];
    let mut scales = vec![0f32; 4];
    for p in [Precision::Ternary, Precision::Nvfp4, Precision::Fp8] {
        let (mean, best) = time_ms(2000, || {
            quant_groups(std::hint::black_box(&x), p, &mut codes, &mut scales);
        });
        t.row(&[format!("quant_groups x64"), format!("{p:?}"), format!("{:.5}", mean), format!("{:.5}", best)]);
    }

    // k-means eviction policy
    let pts: Vec<Vec<f32>> = (0..128).map(|_| {
        let mut v = vec![0f32; 64];
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }).collect();
    let (mean, best) = time_ms(50, || {
        std::hint::black_box(kmeans_select(&pts, 32, 7, 8));
    });
    t.row(&["kmeans_select".into(), "128 keys -> 32".into(), format!("{:.3}", mean), format!("{:.3}", best)]);

    // gather compaction (baseline cost ThinKV avoids)
    let (mean, best) = time_ms(30, || {
        let mut c = Fp32Cache::new(4, 2048, 64, 16);
        let k = vec![1.0f32; 4 * 2048 * 64];
        c.write_prefill(&k.clone(), &k, 2048);
        let evict: Vec<usize> = (0..2048).step_by(2).collect();
        c.evict_positions(&evict);
        c.compact_gather();
    });
    t.row(&["gather_compact".into(), "4L x 2048 x 64".into(), format!("{:.3}", mean), format!("{:.3}", best)]);

    // real PJRT decode steps
    if std::path::Path::new(&format!("{}/model_config.json", thinkv::model::default_artifacts_dir())).exists() {
        let eng = Engine::new().unwrap();
        let m = eng.model().clone();
        for cap in eng.manifest.quant_caps.clone() {
            let (l, hkv, dh, g, b) = (m.n_layers, m.n_kv_heads, m.d_head, m.groups(), m.buf_slots);
            let k_codes = vec![0u8; l * cap * hkv * dh];
            let k_scales = vec![0f32; l * cap * hkv * g];
            let v_codes = k_codes.clone();
            let v_scales = k_scales.clone();
            let tags = vec![1u8; l * cap];
            let mask = vec![1f32; l * cap];
            let buf_k = vec![0f32; l * b * hkv * dh];
            let buf_v = buf_k.clone();
            let buf_mask = vec![0f32; l * b];
            let cache = QuantCache {
                capacity: cap, k_codes: &k_codes, k_scales: &k_scales,
                v_codes: &v_codes, v_scales: &v_scales, tags: &tags, mask: &mask,
                buf_k: &buf_k, buf_v: &buf_v, buf_mask: &buf_mask,
            };
            let _ = eng.decode_quant(1, 0, 0, &cache); // compile
            let (mean, best) = time_ms(30, || {
                let _ = std::hint::black_box(eng.decode_quant(1, 64, 0, &cache));
            });
            t.row(&[format!("decode_quant (PJRT)"), format!("C={cap}"), format!("{:.3}", mean), format!("{:.3}", best)]);
        }
        for cap in [eng.manifest.fp32_caps[0]] {
            let (l, hkv, dh, b) = (m.n_layers, m.n_kv_heads, m.d_head, m.buf_slots);
            let k = vec![0f32; l * cap * hkv * dh];
            let v = k.clone();
            let mask = vec![1f32; l * cap];
            let buf_k = vec![0f32; l * b * hkv * dh];
            let buf_v = buf_k.clone();
            let buf_mask = vec![0f32; l * b];
            let _ = eng.decode_fp32(cap, 1, 0, 0, &k, &v, &mask, &buf_k, &buf_v, &buf_mask);
            let (mean, best) = time_ms(30, || {
                let _ = std::hint::black_box(eng.decode_fp32(cap, 1, 64, 0, &k, &v, &mask, &buf_k, &buf_v, &buf_mask));
            });
            t.row(&["decode_fp32 (PJRT)".into(), format!("C={cap}"), format!("{:.3}", mean), format!("{:.3}", best)]);
        }
        // engine exec-only time share
        println!(
            "\nengine exec totals: {} calls, {:.1} ms total",
            eng.exec_calls.get(),
            eng.exec_nanos.get() as f64 / 1e6
        );
    }
    t.print();
    write_results("micro", t.to_json());
}
