//! Figure 2 + Figure 1(c): accuracy-vs-compression trade-off of
//! quantization-only (KIVI), eviction-only (R-KV), and hybrid (ThinKV),
//! plus the accuracy-vs-TPOT frontier from the GPU cost model.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::quant::Precision;
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, GpuProfile, LrmProfile, ServingCost, Trace};

fn main() {
    let dataset = DatasetProfile::livecodebench();
    let scale = bench_len_scale();
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::gpt_oss_20b());
    let mut t = Table::new(
        "Figure 2 / 1(c): accuracy vs compression vs TPOT (GPT-OSS-20B-profile, LiveCodeBench)",
        &["method", "config", "pass@1", "mem_vs_fullkv_%", "compress_x", "avg_bits", "infl_x", "tpot_ms"],
    );
    let methods: Vec<(String, Method, usize)> = vec![
        ("FullKV".into(), Method::FullKv, usize::MAX),
        ("KIVI".into(), Method::Kivi { prec: Precision::Nvfp4 }, usize::MAX),
        ("KIVI".into(), Method::Kivi { prec: Precision::Ternary }, usize::MAX),
        ("PM-KVQ".into(), Method::PmKvq, usize::MAX),
        ("R-KV".into(), Method::Evict(EvictKind::Rkv), 4096),
        ("R-KV".into(), Method::Evict(EvictKind::Rkv), 1024),
        ("R-KV".into(), Method::Evict(EvictKind::Rkv), 256),
        ("ThinKV".into(), Method::ThinKv(ThinKvSim::default()), 4096),
        ("ThinKV".into(), Method::ThinKv(ThinKvSim::default()), 1024),
        ("ThinKV".into(), Method::ThinKv(ThinKvSim::default()), 256),
    ];
    for (name, m, budget) in methods {
        let mut acc = 0.0;
        let mut mem = 0.0;
        let mut bits = 0.0;
        let mut infl = 0.0;
        let mut gather = 0.0;
        let seeds = bench_seeds();
        for &s in &seeds {
            let trace = Trace::generate(&dataset, s, scale);
            let r = run_method(&trace, &m, &SimConfig { budget, seed: s, stride: 4, rollouts: 32 });
            acc += r.pass1;
            mem += r.mem_frac;
            bits += r.avg_bits;
            infl += r.len_inflation;
            gather += r.gather_bytes_per_step;
        }
        let n = seeds.len() as f64;
        let (acc, mem, bits, infl, gather) = (acc / n, mem / n, bits / n, infl / n, gather / n);
        // TPOT from the cost model (32K-token steady state)
        let live_tokens = if budget == usize::MAX { 32768.0 * infl.min(3.0) } else { budget as f64 };
        let kv = cost.model.kv_bytes_per_token(bits.min(16.0)) * live_tokens;
        let gather_bytes = gather * cost.model.kv_bytes_per_token(16.0);
        let step = cost.decode_step(8, kv, gather_bytes, false, 0.0);
        t.row(&[
            name.clone(),
            if budget == usize::MAX { "-".into() } else { format!("k={budget}") },
            format!("{:.3}", acc),
            format!("{:.2}", mem * 100.0),
            format!("{:.1}", 1.0 / mem.max(1e-9)),
            format!("{:.2}", bits),
            format!("{:.2}", infl),
            format!("{:.2}", cost.tpot_ms(&step)),
        ]);
    }
    t.print();
    write_results("fig2_tradeoff", t.to_json());
    println!("\nExpected shape (paper): hybrid traces the Pareto frontier; 2-bit quantization\ninflates generation (~5x) eroding compression; eviction alone degrades at high\ncompression; ThinKV holds accuracy at the highest compression ratios.");
}
