//! Figure 8: pass@1 vs KV budget across datasets and methods — the paper's
//! main accuracy grid. ThinKV achieves near-lossless accuracy at budgets
//! where token-level baselines collapse.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, Trace};

fn main() {
    let scale = bench_len_scale();
    let seeds = bench_seeds();
    let budgets = [64usize, 256, 1024, 4096];
    let methods: Vec<(&str, Method)> = vec![
        ("ThinKV", Method::ThinKv(ThinKvSim::default())),
        ("R-KV", Method::Evict(EvictKind::Rkv)),
        ("H2O", Method::Evict(EvictKind::H2O)),
        ("LazyEviction", Method::Evict(EvictKind::LazyEviction)),
        ("RaaS", Method::Evict(EvictKind::RaaS)),
        ("StreamingLLM", Method::Evict(EvictKind::StreamingLlm)),
    ];
    for ds in [DatasetProfile::aime(), DatasetProfile::livecodebench(), DatasetProfile::math500()] {
        let mut t = Table::new(
            &format!("Figure 8: pass@1 vs budget — {} (FullKV base {:.1})", ds.name, ds.base_acc * 100.0),
            &["method", "k=64", "k=256", "k=1024", "k=4096", "mem%@1024"],
        );
        for (name, m) in &methods {
            let mut cells = vec![name.to_string()];
            let mut mem1024 = 0.0;
            for &b in &budgets {
                let mut acc = 0.0;
                for &s in &seeds {
                    let trace = Trace::generate(&ds, s, scale);
                    let r = run_method(&trace, m, &SimConfig { budget: b, seed: s, stride: 4, rollouts: 24 });
                    acc += r.pass1;
                    if b == 1024 {
                        mem1024 += r.mem_frac;
                    }
                }
                cells.push(format!("{:.1}", acc / seeds.len() as f64 * 100.0));
            }
            cells.push(format!("{:.2}", mem1024 / seeds.len() as f64 * 100.0));
            t.row(&cells);
        }
        t.print();
        write_results(&format!("fig8_accuracy_{}", ds.name.to_ascii_lowercase().replace('-', "")), t.to_json());
    }
    println!("\nExpected shape (paper): ThinKV near-lossless at k=1024 (<3.7% of FullKV\nmemory) and degrades gracefully to k=64; baselines need >=4x larger budgets\nfor similar accuracy, recency-based ones collapse (anchor loss -> loops).");
}
