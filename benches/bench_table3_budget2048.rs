//! Table 3: conservative 2048-token budget — accuracy-preserving setting
//! still yields a large max-batch / throughput gain over FullKV.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::sim::harness::{Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, GpuProfile, LrmProfile, ServingCost, Trace};

fn main() {
    let model = LrmProfile::r1_llama_8b();
    let cost = ServingCost::new(GpuProfile::a100_80gb(), model.clone());
    let gen = 32_768.0;
    let scale = bench_len_scale();
    let aime = DatasetProfile::aime();
    let acc = |m: &Method, budget: usize| -> f64 {
        let seeds = bench_seeds();
        let mut a = 0.0;
        for &s in &seeds {
            let trace = Trace::generate(&aime, s, scale);
            a += run_method(&trace, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 32 }).pass1;
        }
        a / seeds.len() as f64 * 100.0
    };
    let mut t = Table::new(
        "Table 3: ThinKV @ 2048 budget vs FullKV (R1-Llama-8B, A100, 32K gen)",
        &["method", "acc", "max_batch", "budget", "tok_s"],
    );
    let full_bytes = model.fullkv_bytes_per_token() * gen;
    let b_full = cost.max_batch(full_bytes).max(1);
    let s_full = cost.decode_step(b_full, full_bytes / 2.0, 0.0, false, 0.0);
    t.row(&[
        "FullKV".into(),
        format!("{:.0}", acc(&Method::FullKv, usize::MAX)),
        format!("{b_full}"),
        "-".into(),
        format!("{:.1}", cost.throughput_tok_s(b_full, &s_full)),
    ]);
    let tk_bytes = model.kv_bytes_per_token(3.5) * 2048.0;
    let b_tk = cost.max_batch(tk_bytes).max(1);
    let s_tk = cost.decode_step(b_tk, tk_bytes, 0.0, false, 2.0);
    t.row(&[
        "ThinKV".into(),
        format!("{:.0}", acc(&Method::ThinKv(ThinKvSim::default()), 2048)),
        format!("{b_tk}"),
        "2048".into(),
        format!("{:.1}", cost.throughput_tok_s(b_tk, &s_tk)),
    ]);
    t.print();
    write_results("table3_budget2048", t.to_json());
    println!("\nExpected shape (paper Table 3): accuracy matches FullKV; max batch grows\n~13 -> ~290; throughput gain ~15.8x.");
}
