//! Table 5: per-layer/per-operation time breakdown and call rates,
//! measured on the REAL coordinator (CPU PJRT) for ThinKV vs R-KV, plus
//! the sim-harness call-rate comparison at paper scale.

use thinkv::bench::{bench_len_scale, write_results, Table};
use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, Trace};

fn main() {
    // --- real measured breakdown on the tiny PJRT model ------------------
    if std::path::Path::new(&format!("{}/model_config.json", thinkv::model::default_artifacts_dir())).exists() {
        for (mode, label, budget) in [
            (CompressionMode::thinkv_default(), "ThinKV", 192usize),
            (CompressionMode::Evict(EvictKind::Rkv), "R-KV", 96),
        ] {
            let cfg = ServeConfig {
                mode,
                budget,
                max_new_tokens: 192,
                workers: 1,
                ..ServeConfig::default()
            };
            let c = Coordinator::start(cfg).unwrap();
            let prompt: Vec<i32> = (0..64).map(|i| (i * 5 % 512) as i32).collect();
            let _ = c.submit(prompt.clone()).unwrap().wait(); // warmup/compile
            let r = c.submit(prompt).unwrap().wait().unwrap();
            let mut t = Table::new(
                &format!("Table 5 (measured, CPU PJRT): {label} per-op breakdown"),
                &["operation", "time_%", "calls_%"],
            );
            for (name, pct, calls) in r.breakdown.rows() {
                if pct > 0.005 || calls > 0.0 {
                    t.row(&[name.into(), format!("{pct:.2}"), format!("{calls:.1}")]);
                }
            }
            t.print();
            write_results(&format!("table5_breakdown_{}", label.to_lowercase().replace('-', "")), t.to_json());
        }
    }

    // --- call-rate comparison at paper scale (sim) ------------------------
    let scale = bench_len_scale();
    let aime = DatasetProfile::aime();
    let trace = Trace::generate(&aime, 5, scale);
    let cfgs = SimConfig { budget: 1024, seed: 5, stride: 4, rollouts: 8 };
    let think = run_method(&trace, &Method::ThinKv(ThinKvSim::default()), &cfgs);
    let rkv = run_method(&trace, &Method::Evict(EvictKind::Rkv), &cfgs);
    let mut t = Table::new(
        "Table 5 (call rates, paper-scale sim, k=1024)",
        &["method", "evict_calls_%", "gather_per_step_tokens"],
    );
    t.row(&["ThinKV".into(), format!("{:.2}", think.evict_call_rate * 100.0), "0".into()]);
    t.row(&["R-KV".into(), format!("{:.2}", rkv.evict_call_rate * 100.0), format!("{:.0}", rkv.gather_bytes_per_step)]);
    t.print();
    write_results("table5_callrates", t.to_json());
    println!("\nExpected shape (paper Table 5): ThinKV eviction fires on ~4.6% of steps\n(proactive, segment-granular) vs R-KV ~83% (per-token, budget-saturated);\ngather time is identically zero for ThinKV.");
}
