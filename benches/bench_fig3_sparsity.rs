//! Figure 3: layer-wise attention sparsity across decode steps is
//! tri-modal, with per-thought regimes E < R < T. Validates on simulated
//! traces AND on the real PJRT model's attention rows when artifacts exist.

use thinkv::bench::{write_results, Table};
use thinkv::kvcache::Thought;
use thinkv::sim::{DatasetProfile, Trace};
use thinkv::thought::Kde;

fn main() {
    let mut t = Table::new(
        "Figure 3: attention sparsity tri-modality (simulated R1-Llama-8B, AIME)",
        &["dataset", "modes", "mode_pos", "E_mean", "R_mean", "T_mean"],
    );
    for ds in [DatasetProfile::aime(), DatasetProfile::livecodebench()] {
        let trace = Trace::generate(&ds, 7, 0.5);
        let samples: Vec<f64> = trace.sparsity[trace.prompt_len..].to_vec();
        let kde = Kde::fit(&samples, 256, 1e-3);
        let modes = kde.mode_positions(0.12);
        let mean_of = |th: Thought| {
            let v: Vec<f64> = trace
                .token_thought
                .iter()
                .zip(&trace.sparsity)
                .filter(|(&tt, _)| tt == th)
                .map(|(_, &s)| s)
                .collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        t.row(&[
            ds.name.to_string(),
            format!("{}", modes.len()),
            format!("{:?}", modes.iter().map(|m| (m * 100.0).round() / 100.0).collect::<Vec<_>>()),
            format!("{:.3}", mean_of(Thought::Execution)),
            format!("{:.3}", mean_of(Thought::Reasoning)),
            format!("{:.3}", mean_of(Thought::Transition)),
        ]);
    }
    t.print();
    write_results("fig3_sparsity", t.to_json());
    println!("\nExpected shape (paper Obs 1a/1b): 3 modes; T sparsest, then R, then E.");
}
