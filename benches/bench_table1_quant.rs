//! Table 1: ThinKV vs quantization baselines (KIVI, PM-KVQ) on AIME and
//! LiveCodeBench for two model profiles.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::quant::Precision;
use thinkv::sim::harness::{Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, Trace};

fn main() {
    let scale = bench_len_scale();
    // model profiles: base accuracies from the paper's Table 1
    let models = [("R1-Qwen-14B", (0.5333, 0.4790)), ("QwQ-32B", (0.7333, 0.5545))];
    let mut t = Table::new(
        "Table 1: vs KV quantization baselines (k=1024 for ThinKV)",
        &["model", "method", "bits", "AIME", "LiveCodeBench"],
    );
    for (mname, (acc_aime, acc_lcb)) in models {
        let mut aime = DatasetProfile::aime();
        aime.base_acc = acc_aime;
        let mut lcb = DatasetProfile::livecodebench();
        lcb.base_acc = acc_lcb;
        let eval = |m: &Method, budget: usize| -> (f64, f64, f64) {
            let seeds = bench_seeds();
            let (mut a, mut l, mut bits) = (0.0, 0.0, 0.0);
            for &s in &seeds {
                let ta = Trace::generate(&aime, s, scale);
                let tl = Trace::generate(&lcb, s, scale);
                let ra = run_method(&ta, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 24 });
                let rl = run_method(&tl, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 24 });
                a += ra.pass1;
                l += rl.pass1;
                bits += (ra.avg_bits + rl.avg_bits) / 2.0;
            }
            let n = bench_seeds().len() as f64;
            (a / n * 100.0, l / n * 100.0, bits / n)
        };
        let (a, l, _) = eval(&Method::FullKv, usize::MAX);
        t.row(&[mname.into(), "Baseline".into(), "16-16".into(), format!("{a:.1}"), format!("{l:.1}")]);
        let (a, l, _) = eval(&Method::Kivi { prec: Precision::Ternary }, usize::MAX);
        t.row(&[mname.into(), "KIVI".into(), "2-2".into(), format!("{a:.1}"), format!("{l:.1}")]);
        let (a, l, b) = eval(&Method::PmKvq, usize::MAX);
        t.row(&[mname.into(), "PM-KVQ".into(), format!("{b:.1}"), format!("{a:.1}"), format!("{l:.1}")]);
        let (a, l, b) = eval(&Method::ThinKv(ThinKvSim::default()), 1024);
        t.row(&[mname.into(), "ThinKV (k=1024)".into(), format!("{b:.1}"), format!("{a:.1}"), format!("{l:.1}")]);
    }
    t.print();
    write_results("table1_quant", t.to_json());
    println!("\nExpected shape (paper Table 1): KIVI 2-bit loses 7-15 points; PM-KVQ in\nbetween; ThinKV within a few points of baseline at ~3.4-4.5 effective bits.");
}
