//! Figure 10 ablations: (a) Top-10 recall, (b) eviction curve, (c) refresh
//! rate τ, (d) generation-length inflation, (e) block size vs throughput,
//! (f) thought breakdown per dataset.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::quant::Precision;
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, GpuProfile, LrmProfile, ServingCost, Trace};

fn avg(ds: &DatasetProfile, m: &Method, budget: usize, scale: f64) -> thinkv::sim::SimResult {
    let seeds = bench_seeds();
    let mut out: Option<thinkv::sim::SimResult> = None;
    let n = seeds.len() as f64;
    for &s in &seeds {
        let trace = Trace::generate(ds, s, scale);
        let r = run_method(&trace, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 24 });
        match &mut out {
            None => out = Some(r),
            Some(o) => {
                o.pass1 += r.pass1;
                o.recall10 += r.recall10;
                o.len_inflation += r.len_inflation;
                o.evict_call_rate += r.evict_call_rate;
                o.avg_bits += r.avg_bits;
            }
        }
    }
    let mut o = out.unwrap();
    o.pass1 /= n;
    o.recall10 /= n;
    o.len_inflation /= n;
    o.evict_call_rate /= n;
    o.avg_bits /= n;
    o
}

fn main() {
    let scale = bench_len_scale();
    let aime = DatasetProfile::aime();

    // (a) recall rate of Top-10 attention tokens vs budget
    let mut ta = Table::new(
        "Fig 10(a): Top-10 recall vs budget (R1-Llama-8B profile, AIME)",
        &["method", "k=128", "k=512", "k=1024", "k=2048"],
    );
    for (name, m) in [
        ("ThinKV", Method::ThinKv(ThinKvSim::default())),
        ("R-KV", Method::Evict(EvictKind::Rkv)),
        ("LazyEviction", Method::Evict(EvictKind::LazyEviction)),
    ] {
        let mut row = vec![name.to_string()];
        for b in [128usize, 512, 1024, 2048] {
            row.push(format!("{:.2}", avg(&aime, &m, b, scale).recall10));
        }
        ta.row(&row);
    }
    ta.print();

    // (b) eviction curve: live cache size across a trace
    let trace = Trace::generate(&aime, 3, 0.25);
    let r = run_method(&trace, &Method::ThinKv(ThinKvSim::default()),
                       &SimConfig { budget: 1024, seed: 3, stride: 4, rollouts: 8 });
    println!("\nFig 10(b): ThinKV eviction behavior — avg live {:.0} tokens under budget 1024, \
             eviction active on {:.1}% of steps (proactive, coarse-grained)",
             r.avg_live, r.evict_call_rate * 100.0);

    // (c) refresh rate τ
    let mut tc = Table::new(
        "Fig 10(c): refresh interval τ (GPT-OSS-20B profile, LCB, k=1024)",
        &["tau", "pass@1", "refresh_work_rel"],
    );
    let lcb = DatasetProfile::livecodebench();
    for tau in [32usize, 64, 128, 256, 512] {
        let tk = ThinKvSim { refresh: tau, ..Default::default() };
        let r = avg(&lcb, &Method::ThinKv(tk), 1024, scale);
        tc.row(&[format!("{tau}"), format!("{:.3}", r.pass1), format!("{:.2}", 128.0 / tau as f64)]);
    }
    tc.print();

    // (d) compression -> generation length
    let mut td = Table::new(
        "Fig 10(d): generation-length inflation (R1-Llama-8B profile)",
        &["method", "len_inflation_x"],
    );
    for (name, m) in [
        ("KIVI-2", Method::Kivi { prec: Precision::Ternary }),
        ("KIVI-4", Method::Kivi { prec: Precision::Nvfp4 }),
        ("PM-KVQ", Method::PmKvq),
        ("R-KV (evict-only)", Method::Evict(EvictKind::Rkv)),
        ("ThinKV", Method::ThinKv(ThinKvSim::default())),
    ] {
        td.row(&[name.into(), format!("{:.2}", avg(&aime, &m, 1024, scale).len_inflation)]);
    }
    td.print();

    // (e) block size vs throughput: block-table metadata overhead model +
    // real CtCache write timing per block size
    let mut te = Table::new(
        "Fig 10(e): CT block size vs throughput (A100 profile, k=1024)",
        &["block_size", "metadata_overhead_us", "tok_per_s"],
    );
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b());
    for bs in [4usize, 8, 16, 32, 64] {
        // metadata scan cost grows with segments-per-block; tiny blocks add
        // per-block bookkeeping, large blocks add eviction-scan cost
        let blocks = 1024 / bs;
        let meta_us = blocks as f64 * 0.02 + bs as f64 * bs as f64 * 0.004;
        let kv = cost.model.kv_bytes_per_token(3.4) * 1024.0;
        let step = cost.decode_step(256, kv, 0.0, false, meta_us);
        te.row(&[format!("{bs}"), format!("{:.1}", meta_us), format!("{:.0}", cost.throughput_tok_s(256, &step))]);
    }
    te.print();

    // (f) thought breakdown
    let mut tf = Table::new("Fig 10(f): % thought breakdown", &["dataset", "R%", "E%", "T%"]);
    for ds in [DatasetProfile::aime(), DatasetProfile::livecodebench(), DatasetProfile::math500()] {
        let mut acc = [0.0f64; 3];
        let seeds = bench_seeds();
        for &s in &seeds {
            let b = Trace::generate(&ds, s, scale).thought_breakdown();
            for i in 0..3 {
                acc[i] += b[i];
            }
        }
        let n = seeds.len() as f64;
        tf.row(&[ds.name.into(), format!("{:.0}", acc[0] / n), format!("{:.0}", acc[1] / n), format!("{:.0}", acc[2] / n)]);
    }
    tf.print();

    let mut j = ta.to_json();
    j.set("fig10c", tc.to_json());
    j.set("fig10d", td.to_json());
    j.set("fig10e", te.to_json());
    j.set("fig10f", tf.to_json());
    write_results("fig10_ablations", j);
    println!("\nExpected shapes: (a) ThinKV recall ~FullKV, above token-level heuristics;\n(c) tau=128 best trade-off; (d) KIVI-2 ~5x inflation, ThinKV stable;\n(e) block 8-16 best; (f) AIME has most transitions, MATH fewest.");
}
