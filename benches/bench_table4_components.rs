//! Table 4: component ablation — TBQ alone, TBE alone at several budgets,
//! and the full hybrid; accuracy from the sim harness, iso-batch(8)
//! throughput/latency from the cost model.

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::sim::harness::{Method, SimConfig, ThinKvSim};
use thinkv::sim::{run_method, DatasetProfile, GpuProfile, LrmProfile, ServingCost, Trace};

fn main() {
    let scale = bench_len_scale();
    let mut lcb = DatasetProfile::livecodebench();
    lcb.base_acc = 0.778; // GPT-OSS-20B on LCB (paper Table 4)
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::gpt_oss_20b());
    let gen = 14_166.0;

    let eval = |m: &Method, budget: usize| -> (f64, f64, f64) {
        let seeds = bench_seeds();
        let (mut a, mut bits, mut infl) = (0.0, 0.0, 0.0);
        for &s in &seeds {
            let trace = Trace::generate(&lcb, s, scale);
            let r = run_method(&trace, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 32 });
            a += r.pass1;
            bits += r.avg_bits;
            infl += r.len_inflation;
        }
        let n = seeds.len() as f64;
        (a / n * 100.0, bits / n, infl / n)
    };

    let mut t = Table::new(
        "Table 4: ThinKV components (GPT-OSS-20B profile, LCB, iso-batch 8)",
        &["method", "precision/budget", "acc", "norm_throughput", "norm_latency"],
    );
    let full_kv = cost.model.fullkv_bytes_per_token() * gen / 2.0;
    let base_step = cost.decode_step(8, full_kv, 0.0, false, 0.0);
    let base_tps = cost.throughput_tok_s(8, &base_step);

    let mut add = |name: &str, cfgs: &str, acc: f64, kv_bytes: f64, infl: f64, oh: f64| {
        let step = cost.decode_step(8, kv_bytes, 0.0, false, oh);
        // inflated generations emit more tokens for the same answer: their
        // *useful* throughput divides by the inflation factor
        let tps = cost.throughput_tok_s(8, &step) / infl.max(1.0);
        let lat = step.total_us() / base_step.total_us() * infl.max(1.0);
        t.row(&[
            name.into(),
            cfgs.into(),
            format!("{acc:.1}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.2}x", lat),
        ]);
    };

    add("FullKV", "-", eval(&Method::FullKv, usize::MAX).0, full_kv, 1.0, 0.0);
    let tbq = ThinKvSim { no_tbe: true, ..Default::default() };
    let (a, b, infl) = eval(&Method::ThinKv(tbq), usize::MAX);
    add("TBQ", &format!("{b:.1} bits"), a, cost.model.kv_bytes_per_token(b) * gen / 2.0 * infl.min(2.5), infl, 0.5);
    for budget in [512usize, 1024, 2048] {
        let tbe = ThinKvSim { no_tbq: true, ..Default::default() };
        let (a, _, _) = eval(&Method::ThinKv(tbe), budget);
        add("TBE", &format!("{budget}"), a, cost.model.kv_bytes_per_token(16.0) * budget as f64, 1.0, 2.0);
    }
    let (a, b, infl) = eval(&Method::ThinKv(ThinKvSim::default()), 1024);
    add("ThinKV (TBQ+TBE)", &format!("{b:.1}, 1024"), a, cost.model.kv_bytes_per_token(b) * 1024.0, infl, 2.0);
    t.print();
    write_results("table4_components", t.to_json());
    println!("\nExpected shape (paper Table 4): TBQ alone near-lossless but only ~1.1x\nthroughput (length inflation eats the gain); TBE@512 fast but lossy; hybrid\nkeeps accuracy with ~1.5x iso-batch throughput.");
}
