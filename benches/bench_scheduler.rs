//! Scheduler saturation bench: max admitted batch per GPU (the Tables
//! 2/3 "Batch" column discipline), throughput under oversubscribed
//! offered load, the swap-vs-recompute preemption sweep
//! (suspend-to-host cost vs CoT replay cost), the cross-session
//! batched-decode launch-amortization sweep (one fused engine call per
//! step vs per-session launches), the **shared-prefix
//! common-system-prompt sweep** (max concurrent sessions with vs
//! without cross-session prefix sharing, driven artifact-free on a
//! causal engine fake), and the **arrival-burst chunked-prefill sweep**
//! (running-session TPOT while long prompts prefill whole vs chunked,
//! measured on a deterministic engine-time clock), and the **SLO
//! goodput sweep** (one deterministic multi-tenant arrival trace
//! replayed under throughput-greedy FIFO vs the goodput policy; the
//! slack-ordered scheduler must strictly raise SLO attainment), and the
//! **policy-arena divergence sweep** (every registered eviction policy
//! driven through the live fp32 arena, its retention audit log replayed
//! through the sim-oracle twin; the summed mismatch count is the
//! greppable `policy_divergence=0` gate), and the **skewed-load
//! replica fleet sweep** (one pinned-seed bursty trace with every
//! arrival landed on replica 0, replayed through a singleton vs a
//! 2-replica router whose rebalance pass live-migrates sessions hot →
//! cold; fleet goodput must not lose to the singleton, and the
//! greppable `migrations=` / `lane_width=` lines gate that the fleet
//! actually moved sessions) — plus a real coordinator oversubscription
//! mini-run comparing both preemption policies when artifacts exist.

use std::sync::{mpsc, Arc};

use thinkv::baselines::PolicyKind;
use thinkv::bench::{write_results, Table};
use thinkv::coordinator::{
    advance_batch, CompressionMode, Router, SchedPolicy, Scheduler, ServeConfig, Session,
    SloTarget,
};
use thinkv::kvcache::{BlockPool, PrefixIndex};
use thinkv::sim::{
    replay_divergence, ArrivalTrace, GpuProfile, LrmProfile, ServingCost, TenantClass,
};
use thinkv::testkit::{drive_arena, share_manifest, CausalEngine, MeteredEngine};

fn drain(sched: &Scheduler, engine: &CausalEngine) {
    while sched.inflight() > 0 {
        let batch = sched.next_batch(4).expect("runnable batch while inflight");
        advance_batch(sched, engine, 4, batch);
    }
}

fn main() {
    let model = LrmProfile::r1_llama_8b();
    let gen = 32_768.0;

    // per-request live KV bytes per method (budget 1024 unless FullKV)
    let methods: Vec<(&str, f64)> = vec![
        ("FullKV", model.fullkv_bytes_per_token() * gen / 2.0),
        ("R-KV", model.kv_bytes_per_token(16.0) * 1024.0),
        ("ThinKV", model.kv_bytes_per_token(3.4) * 1024.0),
    ];

    // Part 1: max admitted batch from the byte-accurate pool
    let mut t = Table::new(
        "Scheduler: max admitted batch per GPU (BlockPool admission, R1-Llama-8B)",
        &["method", "kv_MB_per_req", "A100_batch", "GH200_batch"],
    );
    for (name, kv) in &methods {
        let mut cells = vec![name.to_string(), format!("{:.1}", kv / 1e6)];
        for gpu in [GpuProfile::a100_80gb(), GpuProfile::gh200()] {
            // KV pool = device memory minus weights (activation overhead
            // folded into the per-request charge, as ServingCost does)
            let pool_bytes = ((gpu.mem_gb - model.weight_gb) * 1e9) as u64;
            let pool = BlockPool::new(pool_bytes);
            let per_req = (*kv + model.act_gb_per_req * 1e9) as u64;
            cells.push(format!("{}", pool.max_batch(per_req)));
        }
        t.row(&cells);
    }
    t.print();

    // Part 2: saturation sweep — offered load vs throughput + queue depth
    let cost = ServingCost::new(GpuProfile::a100_80gb(), model.clone());
    let mut t2 = Table::new(
        "Scheduler saturation (A100): offered load vs throughput / queue depth",
        &["method", "offered", "admitted", "queued", "tok_s"],
    );
    for (name, kv) in &methods {
        let cap = cost.max_batch(*kv).max(1);
        for offered in [1usize, 8, 32, 128, 512] {
            let admitted = offered.min(cap);
            let queued = offered - admitted;
            let step = cost.decode_step(admitted, *kv, 0.0, false, 0.0);
            t2.row(&[
                name.to_string(),
                format!("{offered}"),
                format!("{admitted}"),
                format!("{queued}"),
                format!("{:.1}", cost.throughput_tok_s(admitted, &step)),
            ]);
        }
    }
    t2.print();

    // Part 3: swap-vs-recompute preemption sweep (ISSUE 2). A preempted
    // request either (a) suspends its live cache over the host link and
    // copies it back later, or (b) replays every decode step generated
    // so far. ThinKV's snapshot is tiny (compressed live set), so swap
    // wins by orders of magnitude; FullKV's snapshot is GBs.
    let mut t3 = Table::new(
        "Preemption reclaim: suspend-to-host swap vs recompute (A100, per preemption)",
        &["method", "cot_tokens", "snapshot_MB", "swap_ms", "recompute_ms", "speedup"],
    );
    for (name, bits, budget) in [
        ("ThinKV", 3.4f64, Some(1024.0f64)),
        ("R-KV", 16.0, Some(1024.0)),
        ("FullKV", 16.0, None),
    ] {
        for cot in [2048usize, 8192, 32_768] {
            // live tokens: budget-capped for compressed methods, the
            // whole CoT for FullKV
            let live = budget.map_or(cot as f64, |b| b.min(cot as f64));
            let snap_bytes = model.kv_bytes_per_token(bits) * live;
            let batch = cost.max_batch(snap_bytes).clamp(1, 64);
            let swap_ms = cost.swap_roundtrip_ms(snap_bytes);
            let rec_ms = cost.recompute_ms(batch, snap_bytes, cot);
            t3.row(&[
                name.to_string(),
                format!("{cot}"),
                format!("{:.1}", snap_bytes / 1e6),
                format!("{swap_ms:.2}"),
                format!("{rec_ms:.1}"),
                format!("{:.0}x", rec_ms / swap_ms.max(1e-9)),
            ]);
        }
    }
    t3.print();

    // Part 4: cross-session batched decode — launch amortization. The
    // fused step pays the kernel-launch overhead once per batch; the
    // per-session regime (pre-batching workers) pays it once per
    // session per step. Byte traffic is identical, so the gap is pure
    // launch amortization and throughput must rise with batch size.
    let mut t4 = Table::new(
        "Batched decode: fused step vs per-session launches (A100, ThinKV b=1024)",
        &["batch", "fused_us", "per_session_us", "launch_save_us", "fused_tok_s", "per_tok_s"],
    );
    let kv_thinkv = model.kv_bytes_per_token(3.4) * 1024.0;
    let single_us = cost.decode_step(1, kv_thinkv, 0.0, false, 0.0).total_us();
    let mut last_tput = 0.0;
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let fused = cost.decode_step(batch, kv_thinkv, 0.0, false, 0.0);
        let per = cost.decode_step_per_session(batch, kv_thinkv, 0.0, false, 0.0);
        let fused_tput = cost.throughput_tok_s(batch, &fused);
        let per_tput = cost.throughput_tok_s(batch, &per);
        // acceptance: throughput grows with decode batch size, and one
        // fused step beats N sequential single-session steps from
        // batch 4 on
        assert!(fused_tput > last_tput, "throughput must rise with batch {batch}");
        if batch >= 4 {
            assert!(
                fused.total_us() < batch as f64 * single_us,
                "fused step must beat {batch} single steps"
            );
        }
        last_tput = fused_tput;
        t4.row(&[
            format!("{batch}"),
            format!("{:.1}", fused.total_us()),
            format!("{:.1}", per.total_us()),
            format!("{:.1}", per.launch_us - fused.launch_us),
            format!("{fused_tput:.1}"),
            format!("{per_tput:.1}"),
        ]);
    }
    t4.print();

    // Part 5: cross-session prefix sharing — the common-system-prompt
    // sweep. Runs artifact-free (causal engine fake): the measured
    // quantity is pool admission, not kernel time. One publisher leaves
    // the system prompt resident; a pool sized for ~1 full prefix + N
    // deltas must then admit all N sharers concurrently, where the
    // unshared path (full-prefix admission) fits only a fraction.
    let mut t6 = Table::new(
        "Prefix sharing: max concurrent sessions, shared vs unshared (pool = 1 prefix + N deltas)",
        &["sharers", "pool_KB", "shared_running", "unshared_running", "hits", "cow"],
    );
    let man = share_manifest();
    let engine = CausalEngine::new(man.model.clone());
    let cfg = ServeConfig {
        mode: CompressionMode::parse("thinkv-notbe").expect("mode"),
        budget: 256,
        max_new_tokens: 6,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let system: Vec<i32> = (0..88).map(|i| ((i * 3) % 60) as i32).collect();
    let prompt_for = |s: usize| -> Vec<i32> {
        let mut p = system.clone();
        p.extend((0..8).map(|i| (s * 8 + i) as i32));
        p
    };
    // measure the byte economics once on an unbounded pool
    let (est, resident, delta) = {
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
        let (tx, rx) = mpsc::channel();
        let publisher = Session::with_parts(
            1,
            prompt_for(0),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        let est = publisher.admission_bytes();
        sched.submit(publisher, tx);
        drain(&sched, &engine);
        let _ = rx.iter().count();
        let probe = Session::with_parts(
            2,
            prompt_for(1),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        (est, idx.stats().resident_bytes, probe.admission_bytes())
    };
    assert!(resident > 0 && delta < est, "sharing must shrink admission");
    let mut total_hits = 0u64;
    let mut total_alias = 0u64;
    for sharers in [2usize, 6, 12] {
        let pool_bytes = (est + resident).max(resident + sharers as u64 * delta) + 4096;
        // shared: publisher first, then N sharers admitted concurrently
        let pool = Arc::new(BlockPool::new(pool_bytes));
        let idx = PrefixIndex::new(Arc::clone(&pool), 8);
        let sched = Scheduler::with_prefix(Arc::clone(&pool), None, Some(Arc::clone(&idx)));
        let (tx, rx) = mpsc::channel();
        let publisher = Session::with_parts(
            1,
            prompt_for(0),
            &cfg,
            &man,
            Some(Arc::clone(&pool)),
            Some(Arc::clone(&idx)),
        )
        .expect("session");
        sched.submit(publisher, tx.clone());
        drain(&sched, &engine);
        for s in 1..=sharers {
            let sess = Session::with_parts(
                s as u64 + 1,
                prompt_for(s),
                &cfg,
                &man,
                Some(Arc::clone(&pool)),
                Some(Arc::clone(&idx)),
            )
            .expect("session");
            sched.submit(sess, tx.clone());
        }
        let shared_running = sched.snapshot().running;
        assert_eq!(
            shared_running, sharers,
            "1 prefix + {sharers} deltas must admit every sharer"
        );
        drain(&sched, &engine);
        drop(tx);
        assert_eq!(rx.iter().filter(|r| r.error.is_none()).count(), sharers + 1);
        let snap = sched.snapshot();
        assert!(snap.pool_peak <= snap.pool_capacity, "pool overflow");
        assert!(snap.prefix_hits as usize >= sharers, "sharers must hit the trie");
        total_hits += snap.prefix_hits;
        // every attach must be the zero-copy alias (block tables pointed
        // at the one resident payload), never the PR-4 attach memcpy
        assert!(
            snap.prefix_alias_hits >= snap.prefix_hits,
            "attaches must alias, not copy ({} alias vs {} hits)",
            snap.prefix_alias_hits,
            snap.prefix_hits
        );
        total_alias += snap.prefix_alias_hits;
        // unshared: the same pool admits far fewer up front
        let pool2 = Arc::new(BlockPool::new(pool_bytes));
        let sched2 = Scheduler::new(Arc::clone(&pool2));
        let (tx2, _rx2) = mpsc::channel();
        for s in 1..=sharers {
            let sess =
                Session::with_pool(s as u64, prompt_for(s), &cfg, &man, Some(Arc::clone(&pool2)))
                    .expect("session");
            sched2.submit(sess, tx2.clone());
        }
        let unshared_running = sched2.snapshot().running;
        assert!(
            unshared_running < sharers || sharers <= (pool_bytes / est) as usize,
            "sharing must multiply admission ({unshared_running} vs {sharers})"
        );
        sched2.shutdown();
        t6.row(&[
            format!("{sharers}"),
            format!("{:.1}", pool_bytes as f64 / 1024.0),
            format!("{shared_running}"),
            format!("{unshared_running}"),
            format!("{}", snap.prefix_hits),
            format!("{}", snap.prefix_cow_faults),
        ]);
        sched.shutdown();
    }
    t6.print();
    // machine-greppable gates: CI asserts the sharing path actually hit
    // and that every hit attached by aliasing (zero-copy)
    println!("prefix_hits={total_hits}");
    assert!(total_hits > 0, "shared-prefix sweep must record hits");
    println!("prefix_alias_hits={total_alias}");
    assert!(total_alias > 0, "shared-prefix sweep must alias, not memcpy");

    // Part 6: arrival-burst sweep — stall-free chunked prefill. A
    // running session decodes while a burst of long prompts arrives;
    // with whole-prompt prefill every arrival head-of-line-blocks the
    // batch for a full inline prefill, with chunked prefill the prompt
    // advances one chunk per fused step between the runner's decode
    // steps. Runs artifact-free on the metered causal fake: engine time
    // is a deterministic logical clock (1 unit per prefill token /
    // decode step), so "TPOT stays flat" is an exact assertion, not a
    // wall-clock flake.
    let mut t7 = Table::new(
        "Chunked prefill: running-session TPOT under a long-prompt arrival burst (engine-time units)",
        &["burst", "policy", "tpot_mean", "tpot_max", "prefill_chunks", "interleaved"],
    );
    const BURST_CHUNK: usize = 16;
    let burst_base = ServeConfig {
        mode: CompressionMode::parse("thinkv").expect("mode"),
        budget: 64,
        max_new_tokens: 512,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    let p_len = man.model.prefill_len;
    let run_burst = |chunk: Option<usize>, burst: usize| {
        let engine = MeteredEngine::new(man.model.clone());
        let pool = Arc::new(BlockPool::new(u64::MAX / 2));
        let sched = Scheduler::new(Arc::clone(&pool));
        if let Some(c) = chunk {
            sched.set_prefill_chunking(c, 0);
        }
        let (tx, rx) = mpsc::channel();
        let runner =
            Session::with_pool(1, prompt_for(0), &burst_base, &man, Some(Arc::clone(&pool)))
                .expect("runner");
        sched.submit(runner, tx.clone());
        // warm the runner into steady decode before the burst lands
        for _ in 0..4 {
            let batch = sched.next_batch(burst + 2).expect("runner runnable");
            advance_batch(&sched, &engine, 4, batch);
        }
        let arr_cfg = ServeConfig { max_new_tokens: 4, ..burst_base.clone() };
        for s in 0..burst {
            let sess = Session::with_pool(
                s as u64 + 2,
                prompt_for(s + 1),
                &arr_cfg,
                &man,
                Some(Arc::clone(&pool)),
            )
            .expect("arrival");
            sched.submit(sess, tx.clone());
        }
        // measure the runner's inter-step gaps while the burst drains
        let start = engine.step_marks().len().saturating_sub(1);
        let mut results = Vec::new();
        while results.len() < burst {
            let batch = sched.next_batch(burst + 2).expect("runnable while inflight");
            advance_batch(&sched, &engine, 4, batch);
            results.extend(rx.try_iter());
        }
        let marks = engine.step_marks();
        let window = &marks[start..];
        let gaps: Vec<u64> = window.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.len() > 1, "runner must decode through the burst");
        let mean = gaps.iter().sum::<u64>() as f64 / gaps.len() as f64;
        let max = gaps.iter().copied().max().unwrap_or(0);
        // let the runner finish so the books balance
        while sched.inflight() > 0 {
            let batch = sched.next_batch(burst + 2).expect("runnable while inflight");
            advance_batch(&sched, &engine, 8, batch);
        }
        drop(tx);
        results.extend(rx.iter());
        assert_eq!(results.iter().filter(|r| r.error.is_none()).count(), burst + 1);
        let snap = sched.snapshot();
        assert!(snap.pool_peak <= snap.pool_capacity, "pool overflow");
        sched.shutdown();
        (mean, max, snap)
    };
    let mut total_interleaved = 0u64;
    let mut total_fused_execs = 0u64;
    for burst in [2usize, 6] {
        let (whole_mean, whole_max, whole_snap) = run_burst(None, burst);
        let (ck_mean, ck_max, ck_snap) = run_burst(Some(BURST_CHUNK), burst);
        // the engine ledger must show one decode execute per fused step
        // (the metered fake mirrors the batched-artifact engine), never
        // one per member
        for snap in [&whole_snap, &ck_snap] {
            assert!(
                snap.pjrt_decode_executes >= snap.fused_steps,
                "ledger lost fused steps ({} execs vs {} steps)",
                snap.pjrt_decode_executes,
                snap.fused_steps
            );
            assert!(
                snap.pjrt_decode_executes < snap.fused_sessions.max(snap.fused_steps + 1),
                "per-member executes leaked into the fused ledger \
                 ({} execs vs {} session-steps)",
                snap.pjrt_decode_executes,
                snap.fused_sessions
            );
            total_fused_execs += snap.pjrt_decode_executes;
        }
        // acceptance: whole-prompt prefill stalls the runner for at
        // least one full prompt; chunked delays it by at most one
        // chunk per step (plus its decode batch-mates), and both TPOT
        // moments drop strictly
        assert!(
            whole_max >= p_len as u64,
            "whole-prompt burst must contain a full-prefill stall (max gap {whole_max})"
        );
        assert!(
            ck_max <= (BURST_CHUNK + burst + 1) as u64,
            "chunked gap {ck_max} exceeds one chunk + batch width"
        );
        assert!(
            ck_mean < whole_mean && ck_max < whole_max,
            "chunked prefill must strictly lower running-session TPOT \
             ({ck_mean:.1}/{ck_max} vs {whole_mean:.1}/{whole_max})"
        );
        assert_eq!(whole_snap.prefill_chunks, 0, "whole-prompt mode runs no chunks");
        assert!(
            ck_snap.prefill_chunks as usize >= burst * (p_len / BURST_CHUNK),
            "every arrival prefills chunk by chunk"
        );
        assert!(ck_snap.prefill_interleaved_steps > 0, "chunks must ride along decode");
        total_interleaved += ck_snap.prefill_interleaved_steps;
        for (policy, mean, max, chunks, inter) in [
            ("whole", whole_mean, whole_max, whole_snap.prefill_chunks, 0),
            ("chunked", ck_mean, ck_max, ck_snap.prefill_chunks, ck_snap.prefill_interleaved_steps),
        ] {
            t7.row(&[
                format!("{burst}"),
                policy.to_string(),
                format!("{mean:.1}"),
                format!("{max}"),
                format!("{chunks}"),
                format!("{inter}"),
            ]);
        }
    }
    t7.print();
    // machine-greppable gate: CI asserts the interleaved-prefill lane
    // actually ran, so the chunked path cannot silently regress to
    // whole-prompt
    println!("prefill_interleaved={total_interleaved}");
    assert!(total_interleaved > 0, "arrival-burst sweep must interleave");
    // machine-greppable gate: the fused-execute ledger recorded real
    // decode executes, one per fused step (artifact-free via the
    // metered engine's mirrored ledger)
    println!("fused_executes={total_fused_execs}");
    assert!(total_fused_execs > 0, "burst sweep must record fused executes");

    // Part 6.5: SLO goodput sweep (ISSUE 7). Replay one deterministic
    // multi-tenant arrival trace twice — throughput-greedy FIFO vs the
    // goodput policy — on the metered causal fake with a pool sized for
    // ~2 concurrent admissions, so arrivals queue. The trace
    // oversubscribes the engine with a steady stream of long math
    // sessions and lands periodic bursts of tight-TTFT chat sessions
    // on top: under FIFO the chats wait out the whole math backlog and
    // blow their deadline, under slack-ordered admission they are
    // lifted over it. Engine time is the scheduler clock
    // (`drive_clock`), so both replays — and their SLO verdicts — are
    // bit-reproducible.
    let mut t9 = Table::new(
        "SLO goodput: deterministic trace replay, throughput policy vs goodput policy (ticks)",
        &["policy", "goodput", "violations", "chat_met", "chat_viol", "chat_ttft_p50", "chat_ttft_p99"],
    );
    let slo_mix = vec![
        TenantClass {
            system_prompt_len: 48,
            tail_len: 16,
            max_new_tokens: 16,
            rate: 0.0,
            burst_every: 20,
            burst_size: 2,
            slo: SloTarget::new(100_000, 0),
            ..TenantClass::math()
        },
        TenantClass {
            system_prompt_len: 16,
            tail_len: 8,
            max_new_tokens: 4,
            rate: 0.0,
            burst_every: 100,
            burst_size: 2,
            slo: SloTarget::new(1_500, 0),
            ..TenantClass::chat()
        },
    ];
    let slo_trace = ArrivalTrace::generate(&slo_mix, 2026, 600, man.model.vocab);
    assert_eq!(
        slo_trace.digest(),
        ArrivalTrace::generate(&slo_mix, 2026, 600, man.model.vocab).digest(),
        "arrival trace must be seed-deterministic"
    );
    println!(
        "slo_trace: {} arrivals ({:?} per class), digest={:016x}",
        slo_trace.events.len(),
        slo_trace.per_class,
        slo_trace.digest()
    );
    let slo_base = ServeConfig {
        mode: CompressionMode::parse("thinkv").expect("mode"),
        budget: 64,
        max_new_tokens: 16,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    // pool for ~2 concurrent admissions of the heaviest class
    let per_adm = Session::new(0, slo_trace.events[0].prompt.clone(), &slo_base, &man)
        .expect("probe")
        .admission_bytes();
    let replay = |goodput: bool| {
        let engine = MeteredEngine::new(man.model.clone());
        let pool = Arc::new(BlockPool::new(per_adm * 2 + 4096));
        let sched = Scheduler::new(Arc::clone(&pool));
        sched.set_prefill_chunking(16, 0);
        if goodput {
            sched.set_policy(SchedPolicy::Goodput);
        }
        let (tx, rx) = mpsc::channel();
        let mut next = 0usize;
        let mut results = Vec::new();
        loop {
            // the engine's logical clock is the arrival timeline
            sched.drive_clock(engine.clock());
            while next < slo_trace.events.len() && slo_trace.events[next].at <= engine.clock() {
                let e = &slo_trace.events[next];
                let cfg = ServeConfig {
                    max_new_tokens: e.max_new_tokens,
                    slo_class: Some(e.class_name.to_string()),
                    slo: e.slo,
                    ..slo_base.clone()
                };
                let sess =
                    Session::with_pool(e.id, e.prompt.clone(), &cfg, &man, Some(Arc::clone(&pool)))
                        .expect("arrival session");
                sched.submit(sess, tx.clone());
                next += 1;
            }
            results.extend(rx.try_iter());
            if results.len() >= slo_trace.events.len() {
                break;
            }
            if sched.inflight() == 0 {
                if next < slo_trace.events.len() {
                    // idle: fast-forward the clock to the next arrival
                    let gap = slo_trace.events[next].at.saturating_sub(engine.clock()).max(1);
                    engine.tick(gap);
                }
                continue;
            }
            let batch = sched.next_batch(4).expect("runnable while inflight");
            advance_batch(&sched, &engine, 2, batch);
        }
        assert!(
            results.iter().all(|r| r.error.is_none()),
            "every replayed arrival must complete cleanly"
        );
        let snap = sched.snapshot();
        sched.shutdown();
        snap
    };
    // same-seed determinism: two independent replays of each policy
    // must produce bit-identical snapshots (counters + percentiles)
    let fifo = replay(false);
    assert_eq!(fifo, replay(false), "throughput replay must be deterministic");
    let slo = replay(true);
    assert_eq!(slo, replay(true), "goodput replay must be deterministic");
    assert!(slo.sched_policy_goodput && !fifo.sched_policy_goodput);
    let chat_of = |s: &thinkv::metrics::SchedSnapshot| {
        s.slo_classes.iter().find(|c| c.name == "chat").cloned().unwrap_or_default()
    };
    let (cf, cg) = (chat_of(&fifo), chat_of(&slo));
    for s in [&fifo, &slo] {
        assert_eq!(s.completions, slo_trace.events.len() as u64, "every arrival completes");
        assert!(s.pool_peak <= s.pool_capacity, "pool overflow");
        // the class ledgers must fold exactly into the global counters
        let by_class: (u64, u64) = s
            .slo_classes
            .iter()
            .fold((0, 0), |(g, v), c| (g + c.goodput, v + c.violations));
        assert_eq!(by_class, (s.goodput, s.slo_violations), "class ledgers out of sync");
        assert!(s.goodput + s.slo_violations <= s.completions, "goodput over-counted");
    }
    // both policies serve the same classed arrivals; the goodput policy
    // must strictly convert more of them into met SLOs — that is the
    // whole point of deadline-slack scheduling
    assert_eq!(
        fifo.goodput + fifo.slo_violations,
        slo.goodput + slo.slo_violations,
        "policies must score the same classed population"
    );
    assert!(
        slo.goodput > fifo.goodput,
        "goodput policy must strictly beat FIFO ({} vs {})",
        slo.goodput,
        fifo.goodput
    );
    assert!(
        cg.goodput > cf.goodput && cg.violations <= cf.violations,
        "the win must come from the tight-TTFT chat class \
         (goodput {} vs {}, violations {} vs {})",
        cg.goodput,
        cf.goodput,
        cg.violations,
        cf.violations
    );
    for (name, s, c) in [("throughput", &fifo, &cf), ("goodput", &slo, &cg)] {
        t9.row(&[
            name.to_string(),
            format!("{}", s.goodput),
            format!("{}", s.slo_violations),
            format!("{}", c.goodput),
            format!("{}", c.violations),
            format!("{}", c.ttft_p50),
            format!("{}", c.ttft_p99),
        ]);
    }
    t9.print();
    // machine-greppable gate: CI asserts the goodput-policy replay
    // actually met SLOs, so the slack-ordered path cannot silently
    // regress to never-scoring
    println!("goodput={}", slo.goodput);
    assert!(slo.goodput > 0, "goodput replay must meet SLOs");

    // Part 6.75: policy-arena divergence sweep (ISSUE 8). Drive every
    // registered eviction policy through the live fp32 arena with the
    // retention audit log on, then replay each recorded history through
    // the sim-oracle twin. The summed mismatch count is the
    // machine-greppable gate: any live/sim drift — a policy losing
    // state, a nondeterministic tiebreak, an audit event recorded out
    // of order — surfaces as a nonzero divergence.
    let mut t10 = Table::new(
        "Policy arena: live-vs-sim replay divergence (fp32 arena, audit-log replay)",
        &["policy", "events", "evicted", "skipped", "retained_B", "mismatches"],
    );
    let mut total_mismatches = 0usize;
    for kind in PolicyKind::ALL {
        let run = drive_arena(kind, 24, 40, 7);
        let d = replay_divergence(&run.trace);
        total_mismatches += d.mismatches;
        t10.row(&[
            kind.name().to_string(),
            format!("{}", d.events),
            format!("{}", run.counters.evicted),
            format!("{}", run.counters.skipped),
            format!("{}", run.counters.retained_bytes),
            format!("{}", d.mismatches),
        ]);
    }
    t10.print();
    // machine-greppable gate: CI greps this line for exactly 0, so a
    // policy whose live decisions stop replaying in the sim twin fails
    // the bench-smoke lane even before the conformance suite runs
    println!("policy_divergence={total_mismatches}");
    assert_eq!(total_mismatches, 0, "live policies must replay exactly in the sim twin");

    // Part 6.9: skewed-load replica fleet sweep (ISSUE 9). One
    // pinned-seed bursty arrival trace replayed twice: every arrival
    // pinned onto replica 0 of a singleton, then the same skewed
    // arrivals in front of a 2-replica Router whose per-loop rebalance
    // pass live-migrates queued sessions off the hot replica through
    // the KvSnapshot path. Each replica owns a MeteredEngine; the
    // logical clocks are synced to the fleet max every loop, so the
    // replay and its SLO verdicts are engine-time deterministic. The
    // fleet must convert at least as many arrivals into met SLOs as
    // the singleton, and must actually migrate to do it.
    let mut t11 = Table::new(
        "Replica fleet: pinned-seed skewed trace, singleton vs 2-replica router (live migration)",
        &["fleet", "goodput", "violations", "migrations", "migration_KB", "lane_peak"],
    );
    let fleet_mix = vec![
        TenantClass {
            system_prompt_len: 48,
            tail_len: 16,
            max_new_tokens: 16,
            rate: 0.0,
            burst_every: 20,
            burst_size: 2,
            slo: SloTarget::new(100_000, 0),
            ..TenantClass::math()
        },
        TenantClass {
            system_prompt_len: 16,
            tail_len: 8,
            max_new_tokens: 4,
            rate: 0.0,
            burst_every: 100,
            burst_size: 2,
            slo: SloTarget::new(1_500, 0),
            ..TenantClass::chat()
        },
    ];
    let fleet_trace = ArrivalTrace::generate(&fleet_mix, 909, 600, man.model.vocab);
    assert!(!fleet_trace.events.is_empty());
    let fleet_base = ServeConfig {
        mode: CompressionMode::parse("thinkv").expect("mode"),
        budget: 64,
        max_new_tokens: 16,
        workers: 1,
        temperature: 0.0,
        ..ServeConfig::default()
    };
    // per-replica pool: two admissions of the heaviest arrival, so the
    // hot replica queues and the rebalance pass has work to move
    let max_adm = fleet_trace
        .events
        .iter()
        .map(|e| {
            Session::new(0, e.prompt.clone(), &fleet_base, &man)
                .expect("probe")
                .admission_bytes()
        })
        .max()
        .expect("nonempty trace");
    let fleet_replay = |replicas: usize| {
        let router = Router::new(replicas, max_adm * 2 + 4096, Some(64u64 << 20), false, 16);
        let engines: Vec<MeteredEngine> =
            (0..replicas).map(|_| MeteredEngine::new(man.model.clone())).collect();
        let (tx, rx) = mpsc::channel();
        let mut next = 0usize;
        let mut results = Vec::new();
        loop {
            // sync every engine (and scheduler clock) to the fleet max
            let now = engines.iter().map(|e| e.clock()).max().expect("engines");
            for (i, e) in engines.iter().enumerate() {
                let behind = now.saturating_sub(e.clock());
                if behind > 0 {
                    e.tick(behind);
                }
                router.replicas()[i].scheduler().drive_clock(now);
            }
            // the skew: every arrival lands on replica 0
            while next < fleet_trace.events.len() && fleet_trace.events[next].at <= now {
                let e = &fleet_trace.events[next];
                let cfg = ServeConfig {
                    max_new_tokens: e.max_new_tokens,
                    slo_class: Some(e.class_name.to_string()),
                    slo: e.slo,
                    ..fleet_base.clone()
                };
                let pool = Arc::clone(router.replicas()[0].scheduler().pool());
                let sess = Session::with_pool(e.id, e.prompt.clone(), &cfg, &man, Some(pool))
                    .expect("arrival session");
                router.submit_to(0, sess, tx.clone());
                next += 1;
            }
            results.extend(rx.try_iter());
            if results.len() >= fleet_trace.events.len() {
                break;
            }
            if router.inflight() == 0 {
                if next < fleet_trace.events.len() {
                    let gap = fleet_trace.events[next].at.saturating_sub(now).max(1);
                    engines[0].tick(gap);
                }
                continue;
            }
            router.rebalance();
            for (i, r) in router.replicas().iter().enumerate() {
                let sched = r.scheduler();
                if sched.inflight() > 0 {
                    let batch = sched.next_batch(4).expect("runnable while inflight");
                    advance_batch(sched, &engines[i], 2, batch);
                }
            }
        }
        assert!(
            results.iter().all(|r: &thinkv::coordinator::RequestResult| r.error.is_none()),
            "every fleet arrival must complete cleanly"
        );
        let snap = router.snapshot();
        assert_eq!(snap.completions, fleet_trace.events.len() as u64);
        assert!(snap.pool_peak <= snap.pool_capacity, "pool overflow");
        router.shutdown();
        snap
    };
    let single = fleet_replay(1);
    let fleet = fleet_replay(2);
    assert_eq!(single.migrations, 0, "a singleton has nowhere to migrate");
    assert!(fleet.migrations > 0, "the skewed fleet must live-migrate");
    assert!(fleet.migration_bytes > 0, "migrated snapshots move bytes");
    assert_eq!(
        single.goodput + single.slo_violations,
        fleet.goodput + fleet.slo_violations,
        "both fleets must score the same classed population"
    );
    assert!(
        fleet.goodput >= single.goodput,
        "2-replica goodput must not lose to the singleton ({} vs {})",
        fleet.goodput,
        single.goodput
    );
    for (name, s) in [("singleton", &single), ("2-replica", &fleet)] {
        t11.row(&[
            name.to_string(),
            format!("{}", s.goodput),
            format!("{}", s.slo_violations),
            format!("{}", s.migrations),
            format!("{:.1}", s.migration_bytes as f64 / 1024.0),
            format!("{}", s.lane_peak),
        ]);
    }
    t11.print();
    // machine-greppable gates: CI asserts the fleet actually migrated
    // and the lane bookkeeping saw real batch lanes, so the replica
    // tier cannot silently regress to never-moving sessions
    println!("migrations={}", fleet.migrations);
    assert!(fleet.migrations > 0, "fleet sweep must record migrations");
    println!("lane_width={}", fleet.lane_peak.max(single.lane_peak));
    assert!(fleet.lane_peak > 0, "fleet sweep must record lane widths");

    // Part 7: real coordinator oversubscription mini-run (CPU PJRT),
    // recompute preemption vs suspend-to-host swap
    let artifacts = format!("{}/model_config.json", thinkv::model::default_artifacts_dir());
    let mut j = t.to_json();
    j.set("saturation", t2.to_json());
    j.set("swap_vs_recompute", t3.to_json());
    j.set("launch_amortization", t4.to_json());
    j.set("prefix_sharing", t6.to_json());
    j.set("arrival_burst", t7.to_json());
    j.set("slo_goodput", t9.to_json());
    j.set("policy_arena", t10.to_json());
    j.set("replica_fleet", t11.to_json());
    if std::path::Path::new(&artifacts).exists()
        && std::env::var("THINKV_BENCH_REAL").map(|v| v == "1").unwrap_or(true)
    {
        use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig, Session};
        let manifest =
            thinkv::model::Manifest::load(&thinkv::model::default_artifacts_dir()).unwrap();
        let base = ServeConfig {
            mode: CompressionMode::thinkv_default(),
            budget: 128,
            max_new_tokens: 24,
            workers: 2,
            temperature: 0.0,
            ..ServeConfig::default()
        };
        let probe = Session::new(0, vec![1, 2, 3], &base, &manifest).unwrap();
        let per = probe.admission_bytes();
        let mut t5 = Table::new(
            "Real coordinator oversubscription (CPU PJRT, pool = 2.5 admissions): swap vs recompute",
            &[
                "requests", "policy", "completed", "wall_s", "preempts", "swap_ins",
                "replayed_steps", "peak_B", "fused_steps", "avg_batch",
            ],
        );
        for requests in [2usize, 8] {
            for swap in [None, Some(256u64 << 20)] {
                let cfg = ServeConfig {
                    pool_bytes: Some(per * 5 / 2),
                    swap_bytes: swap,
                    ..base.clone()
                };
                let c = Coordinator::start(cfg).unwrap();
                let prompts: Vec<Vec<i32>> = (0..requests)
                    .map(|u| (0..64).map(|i| ((i * 3 + u) % 512) as i32).collect())
                    .collect();
                let t0 = std::time::Instant::now();
                let rs = c.run_batch(prompts).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                let s = c.sched_stats();
                assert!(s.pool_peak <= s.pool_capacity, "pool overflow");
                // decode steps beyond the tokens delivered = replay waste
                let replayed: u64 = rs
                    .iter()
                    .map(|r| r.breakdown.steps.saturating_sub(r.tokens.len() as u64))
                    .sum();
                if swap.is_some() {
                    assert_eq!(replayed, 0, "swapped sessions must not replay");
                }
                // every decode step goes through the fused entry point,
                // even when the batch happens to hold one session
                assert!(s.fused_steps > 0, "no fused decode steps recorded");
                t5.row(&[
                    format!("{requests}"),
                    if swap.is_some() { "swap" } else { "recompute" }.to_string(),
                    format!("{}", rs.iter().filter(|r| r.error.is_none()).count()),
                    format!("{wall:.2}"),
                    format!("{}", s.preemptions),
                    format!("{}", s.swap_ins),
                    format!("{replayed}"),
                    format!("{}", s.pool_peak),
                    format!("{}", s.fused_steps),
                    format!("{:.2}", s.fused_sessions as f64 / s.fused_steps.max(1) as f64),
                ]);
            }
        }
        t5.print();
        j.set("real_oversubscription", t5.to_json());

        // Part 8: measured launch amortization (CPU PJRT). Time the
        // real batched-decode artifacts across compiled widths plus the
        // single-lane artifact, verify the ledger (exactly one PJRT
        // execute per fused call, zero fallback), extract the
        // per-execute launch intercept from the measured sweep, and
        // re-anchor the analytic ServingCost terms against measured
        // numbers — then re-validate every analytically-priced
        // assertion under the measured anchors.
        use thinkv::kvcache::{CacheConfig, CtCache};
        use thinkv::runtime::{BatchDecodeReq, CacheView, DecodeEngine, Engine};
        let eng = Engine::new().unwrap();
        let m = eng.model().clone();
        let p = m.prefill_len;
        let prompt: Vec<i32> = (0..p as i32).map(|i| (i * 11) % m.vocab as i32).collect();
        let pf = eng.prefill(&prompt).unwrap();
        let cap = *eng.manifest.quant_caps.iter().min().expect("quant cap");
        let mut widths = eng.manifest.batch_widths.clone();
        widths.sort_unstable();
        let max_w = *widths.last().expect("batched artifacts compiled");
        let caches: Vec<CtCache> = (0..max_w)
            .map(|_| {
                let mut c = CtCache::new(CacheConfig {
                    layers: m.n_layers,
                    capacity: cap,
                    block_size: 8,
                    hkv: m.n_kv_heads,
                    dh: m.d_head,
                    buf_slots: m.buf_slots,
                });
                c.write_prefill(&pf.k, &pf.v, p, thinkv::quant::Precision::Fp8);
                c
            })
            .collect();
        let reps = 10u32;
        let mut t8 = Table::new(
            "Measured fused executes (CPU PJRT): batched artifact vs N single executes",
            &["batch", "fused_us", "n_singles_us", "speedup"],
        );
        // single-lane baseline (the per-member fallback cost)
        let single_us = {
            let view = caches[0].view();
            for _ in 0..3 {
                eng.decode_quant(17, p as i32, 0, &view).unwrap();
            }
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                eng.decode_quant(17, p as i32, 0, &view).unwrap();
            }
            t0.elapsed().as_secs_f64() * 1e6 / reps as f64
        };
        let mut points: Vec<(usize, f64)> = Vec::new();
        for &b in &widths {
            let reqs: Vec<BatchDecodeReq> = caches[..b]
                .iter()
                .map(|c| BatchDecodeReq {
                    token: 17,
                    pos: p as i32,
                    buf_idx: 0,
                    view: CacheView::Quant(c.view()),
                })
                .collect();
            for _ in 0..3 {
                eng.decode_batch(&reqs).unwrap();
            }
            let es0 = eng.exec_stats();
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                eng.decode_batch(&reqs).unwrap();
            }
            let fused_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
            let es1 = eng.exec_stats();
            // acceptance: exactly 1 PJRT execute per fused step when a
            // compiled width covers the batch, and no counted fallback
            assert_eq!(
                es1.decode_executes - es0.decode_executes,
                reps as u64,
                "width {b}: fused step must issue exactly 1 PJRT execute"
            );
            assert_eq!(
                es1.fallback_executes, es0.fallback_executes,
                "width {b}: compiled width must not fall back"
            );
            // acceptance: measured (not analytic) amortization — one
            // fused execute beats N single executes from batch 4 on
            if b >= 4 {
                assert!(
                    fused_us < b as f64 * single_us,
                    "measured fused {fused_us:.0} us must beat {b} x single {single_us:.0} us"
                );
            }
            t8.row(&[
                format!("{b}"),
                format!("{fused_us:.0}"),
                format!("{:.0}", b as f64 * single_us),
                format!("{:.2}x", b as f64 * single_us / fused_us.max(1e-9)),
            ]);
            points.push((b, fused_us));
        }
        t8.print();
        // re-anchor the analytic model: launch intercept from the
        // measured width sweep, host link from a measured host memcpy
        let intercept = ServingCost::launch_intercept_us(&points).unwrap_or(0.0);
        let launch_per_layer = intercept / m.n_layers as f64;
        let copy_bytes = 32usize << 20;
        let src = vec![1u8; copy_bytes];
        let t0 = std::time::Instant::now();
        let dst = src.clone();
        let link_gbps = copy_bytes as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e9;
        assert_eq!(dst[copy_bytes - 1], 1);
        let mut mcost = cost.clone();
        mcost.reanchor(launch_per_layer, link_gbps);
        println!(
            "reanchored: single={single_us:.0} us, launch_intercept={intercept:.1} us \
             ({launch_per_layer:.2} us/layer), host_link={link_gbps:.1} GB/s"
        );
        // every analytically-priced assertion re-validated under the
        // measured anchors (not the datasheet guesses)
        let kv = model.kv_bytes_per_token(3.4) * 1024.0;
        let single_step = mcost.decode_step(1, kv, 0.0, false, 0.0);
        let mut last = 0.0;
        for batch in [1usize, 2, 4, 8, 16, 32] {
            let fused = mcost.decode_step(batch, kv, 0.0, false, 0.0);
            let per = mcost.decode_step_per_session(batch, kv, 0.0, false, 0.0);
            assert!(fused.total_us() <= per.total_us(), "fused must not exceed per-session");
            if batch >= 4 {
                assert!(
                    fused.total_us() < batch as f64 * single_step.total_us(),
                    "reanchored fused step must amortize at batch {batch}"
                );
            }
            let tput = mcost.throughput_tok_s(batch, &fused);
            assert!(tput > last, "reanchored throughput must rise with batch {batch}");
            last = tput;
        }
        let snap_bytes = model.kv_bytes_per_token(3.4) * 1024.0;
        assert!(
            mcost.swap_roundtrip_ms(snap_bytes) * 100.0
                < mcost.recompute_ms(32, snap_bytes, 8_192),
            "swap must still beat recompute under the measured host link"
        );
        let mut jm = thinkv::util::json::Json::obj();
        jm.set("single_us", thinkv::util::json::Json::Num(single_us));
        jm.set("launch_intercept_us", thinkv::util::json::Json::Num(intercept));
        jm.set("host_link_gbps", thinkv::util::json::Json::Num(link_gbps));
        j.set("measured_amortization", jm);
    } else {
        // explicit skip, never silent: CI greps this line on
        // artifact-free runners so the lane's absence is visible
        println!(
            "skipping real-coordinator + measured-execute lanes: artifacts missing \
             (run `make artifacts`) or THINKV_BENCH_REAL=0"
        );
    }
    write_results("scheduler_saturation", j);
    println!("\nExpected shape: FullKV admits ~13 requests on A100 while ThinKV admits\nhundreds; past saturation the scheduler queues instead of overflowing, and\nthe real run completes every request with pool.peak() <= capacity. In the\nswap-vs-recompute sweep ThinKV's suspend-to-host round trip is orders of\nmagnitude cheaper than replaying the CoT (and the real swap run finishes\nwith zero replayed steps), while FullKV must move GBs per preemption. The\nlaunch-amortization sweep shows fused-step throughput rising with decode\nbatch size: one fused call per step beats N per-session launches (the\nTables 2/3 large-batch regime). The prefix-sharing sweep shows a pool\nsized for one resident system prompt plus N deltas admitting all N\nsharers concurrently while full-prefix admission fits only a fraction.\nThe arrival-burst sweep shows running-session TPOT staying flat under\nchunked prefill (max gap = one chunk + batch width) where whole-prompt\nprefill stalls it for a full prefill per arrival.");
}
