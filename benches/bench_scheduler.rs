//! Scheduler saturation bench: max admitted batch per GPU (the Tables
//! 2/3 "Batch" column discipline) and throughput under oversubscribed
//! offered load, using the analytic cost model — plus a real
//! coordinator oversubscription mini-run when artifacts exist.

use thinkv::bench::{write_results, Table};
use thinkv::kvcache::BlockPool;
use thinkv::sim::{GpuProfile, LrmProfile, ServingCost};

fn main() {
    let model = LrmProfile::r1_llama_8b();
    let gen = 32_768.0;

    // per-request live KV bytes per method (budget 1024 unless FullKV)
    let methods: Vec<(&str, f64)> = vec![
        ("FullKV", model.fullkv_bytes_per_token() * gen / 2.0),
        ("R-KV", model.kv_bytes_per_token(16.0) * 1024.0),
        ("ThinKV", model.kv_bytes_per_token(3.4) * 1024.0),
    ];

    // Part 1: max admitted batch from the byte-accurate pool
    let mut t = Table::new(
        "Scheduler: max admitted batch per GPU (BlockPool admission, R1-Llama-8B)",
        &["method", "kv_MB_per_req", "A100_batch", "GH200_batch"],
    );
    for (name, kv) in &methods {
        let mut cells = vec![name.to_string(), format!("{:.1}", kv / 1e6)];
        for gpu in [GpuProfile::a100_80gb(), GpuProfile::gh200()] {
            // KV pool = device memory minus weights (activation overhead
            // folded into the per-request charge, as ServingCost does)
            let pool_bytes = ((gpu.mem_gb - model.weight_gb) * 1e9) as u64;
            let pool = BlockPool::new(pool_bytes);
            let per_req = (*kv + model.act_gb_per_req * 1e9) as u64;
            cells.push(format!("{}", pool.max_batch(per_req)));
        }
        t.row(&cells);
    }
    t.print();

    // Part 2: saturation sweep — offered load vs throughput + queue depth
    let cost = ServingCost::new(GpuProfile::a100_80gb(), model.clone());
    let mut t2 = Table::new(
        "Scheduler saturation (A100): offered load vs throughput / queue depth",
        &["method", "offered", "admitted", "queued", "tok_s"],
    );
    for (name, kv) in &methods {
        let cap = cost.max_batch(*kv).max(1);
        for offered in [1usize, 8, 32, 128, 512] {
            let admitted = offered.min(cap);
            let queued = offered - admitted;
            let step = cost.decode_step(admitted, *kv, 0.0, false, 0.0);
            t2.row(&[
                name.to_string(),
                format!("{offered}"),
                format!("{admitted}"),
                format!("{queued}"),
                format!("{:.1}", cost.throughput_tok_s(admitted, &step)),
            ]);
        }
    }
    t2.print();

    // Part 3: real coordinator oversubscription mini-run (CPU PJRT)
    let artifacts = format!("{}/model_config.json", thinkv::model::default_artifacts_dir());
    let mut j = t.to_json();
    j.set("saturation", t2.to_json());
    if std::path::Path::new(&artifacts).exists()
        && std::env::var("THINKV_BENCH_REAL").map(|v| v == "1").unwrap_or(true)
    {
        use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig, Session};
        let manifest =
            thinkv::model::Manifest::load(&thinkv::model::default_artifacts_dir()).unwrap();
        let base = ServeConfig {
            mode: CompressionMode::thinkv_default(),
            budget: 128,
            max_new_tokens: 24,
            workers: 2,
            temperature: 0.0,
            ..ServeConfig::default()
        };
        let probe = Session::new(0, vec![1, 2, 3], &base, &manifest).unwrap();
        let per = probe.admission_bytes();
        let mut t3 = Table::new(
            "Real coordinator oversubscription (CPU PJRT, pool = 2.5 admissions)",
            &["requests", "completed", "admissions", "preemptions", "peak_B", "cap_B"],
        );
        for requests in [2usize, 8] {
            let cfg = ServeConfig { pool_bytes: Some(per * 5 / 2), ..base.clone() };
            let c = Coordinator::start(cfg).unwrap();
            let prompts: Vec<Vec<i32>> = (0..requests)
                .map(|u| (0..64).map(|i| ((i * 3 + u) % 512) as i32).collect())
                .collect();
            let rs = c.run_batch(prompts).unwrap();
            let s = c.sched_stats();
            assert!(s.pool_peak <= s.pool_capacity, "pool overflow");
            t3.row(&[
                format!("{requests}"),
                format!("{}", rs.iter().filter(|r| r.error.is_none()).count()),
                format!("{}", s.admissions),
                format!("{}", s.preemptions),
                format!("{}", s.pool_peak),
                format!("{}", s.pool_capacity),
            ]);
        }
        t3.print();
        j.set("real_oversubscription", t3.to_json());
    }
    write_results("scheduler_saturation", j);
    println!("\nExpected shape: FullKV admits ~13 requests on A100 while ThinKV admits\nhundreds; past saturation the scheduler queues instead of overflowing, and\nthe real run completes every request with pool.peak() <= capacity.");
}
