//! Scheduler saturation bench: max admitted batch per GPU (the Tables
//! 2/3 "Batch" column discipline), throughput under oversubscribed
//! offered load, the swap-vs-recompute preemption sweep
//! (suspend-to-host cost vs CoT replay cost), and the cross-session
//! batched-decode launch-amortization sweep (one fused engine call per
//! step vs per-session launches), using the analytic cost model — plus
//! a real coordinator oversubscription mini-run comparing both
//! preemption policies when artifacts exist.

use thinkv::bench::{write_results, Table};
use thinkv::kvcache::BlockPool;
use thinkv::sim::{GpuProfile, LrmProfile, ServingCost};

fn main() {
    let model = LrmProfile::r1_llama_8b();
    let gen = 32_768.0;

    // per-request live KV bytes per method (budget 1024 unless FullKV)
    let methods: Vec<(&str, f64)> = vec![
        ("FullKV", model.fullkv_bytes_per_token() * gen / 2.0),
        ("R-KV", model.kv_bytes_per_token(16.0) * 1024.0),
        ("ThinKV", model.kv_bytes_per_token(3.4) * 1024.0),
    ];

    // Part 1: max admitted batch from the byte-accurate pool
    let mut t = Table::new(
        "Scheduler: max admitted batch per GPU (BlockPool admission, R1-Llama-8B)",
        &["method", "kv_MB_per_req", "A100_batch", "GH200_batch"],
    );
    for (name, kv) in &methods {
        let mut cells = vec![name.to_string(), format!("{:.1}", kv / 1e6)];
        for gpu in [GpuProfile::a100_80gb(), GpuProfile::gh200()] {
            // KV pool = device memory minus weights (activation overhead
            // folded into the per-request charge, as ServingCost does)
            let pool_bytes = ((gpu.mem_gb - model.weight_gb) * 1e9) as u64;
            let pool = BlockPool::new(pool_bytes);
            let per_req = (*kv + model.act_gb_per_req * 1e9) as u64;
            cells.push(format!("{}", pool.max_batch(per_req)));
        }
        t.row(&cells);
    }
    t.print();

    // Part 2: saturation sweep — offered load vs throughput + queue depth
    let cost = ServingCost::new(GpuProfile::a100_80gb(), model.clone());
    let mut t2 = Table::new(
        "Scheduler saturation (A100): offered load vs throughput / queue depth",
        &["method", "offered", "admitted", "queued", "tok_s"],
    );
    for (name, kv) in &methods {
        let cap = cost.max_batch(*kv).max(1);
        for offered in [1usize, 8, 32, 128, 512] {
            let admitted = offered.min(cap);
            let queued = offered - admitted;
            let step = cost.decode_step(admitted, *kv, 0.0, false, 0.0);
            t2.row(&[
                name.to_string(),
                format!("{offered}"),
                format!("{admitted}"),
                format!("{queued}"),
                format!("{:.1}", cost.throughput_tok_s(admitted, &step)),
            ]);
        }
    }
    t2.print();

    // Part 3: swap-vs-recompute preemption sweep (ISSUE 2). A preempted
    // request either (a) suspends its live cache over the host link and
    // copies it back later, or (b) replays every decode step generated
    // so far. ThinKV's snapshot is tiny (compressed live set), so swap
    // wins by orders of magnitude; FullKV's snapshot is GBs.
    let mut t3 = Table::new(
        "Preemption reclaim: suspend-to-host swap vs recompute (A100, per preemption)",
        &["method", "cot_tokens", "snapshot_MB", "swap_ms", "recompute_ms", "speedup"],
    );
    for (name, bits, budget) in [
        ("ThinKV", 3.4f64, Some(1024.0f64)),
        ("R-KV", 16.0, Some(1024.0)),
        ("FullKV", 16.0, None),
    ] {
        for cot in [2048usize, 8192, 32_768] {
            // live tokens: budget-capped for compressed methods, the
            // whole CoT for FullKV
            let live = budget.map_or(cot as f64, |b| b.min(cot as f64));
            let snap_bytes = model.kv_bytes_per_token(bits) * live;
            let batch = cost.max_batch(snap_bytes).clamp(1, 64);
            let swap_ms = cost.swap_roundtrip_ms(snap_bytes);
            let rec_ms = cost.recompute_ms(batch, snap_bytes, cot);
            t3.row(&[
                name.to_string(),
                format!("{cot}"),
                format!("{:.1}", snap_bytes / 1e6),
                format!("{swap_ms:.2}"),
                format!("{rec_ms:.1}"),
                format!("{:.0}x", rec_ms / swap_ms.max(1e-9)),
            ]);
        }
    }
    t3.print();

    // Part 4: cross-session batched decode — launch amortization. The
    // fused step pays the kernel-launch overhead once per batch; the
    // per-session regime (pre-batching workers) pays it once per
    // session per step. Byte traffic is identical, so the gap is pure
    // launch amortization and throughput must rise with batch size.
    let mut t4 = Table::new(
        "Batched decode: fused step vs per-session launches (A100, ThinKV b=1024)",
        &["batch", "fused_us", "per_session_us", "launch_save_us", "fused_tok_s", "per_tok_s"],
    );
    let kv_thinkv = model.kv_bytes_per_token(3.4) * 1024.0;
    let single_us = cost.decode_step(1, kv_thinkv, 0.0, false, 0.0).total_us();
    let mut last_tput = 0.0;
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let fused = cost.decode_step(batch, kv_thinkv, 0.0, false, 0.0);
        let per = cost.decode_step_per_session(batch, kv_thinkv, 0.0, false, 0.0);
        let fused_tput = cost.throughput_tok_s(batch, &fused);
        let per_tput = cost.throughput_tok_s(batch, &per);
        // acceptance: throughput grows with decode batch size, and one
        // fused step beats N sequential single-session steps from
        // batch 4 on
        assert!(fused_tput > last_tput, "throughput must rise with batch {batch}");
        if batch >= 4 {
            assert!(
                fused.total_us() < batch as f64 * single_us,
                "fused step must beat {batch} single steps"
            );
        }
        last_tput = fused_tput;
        t4.row(&[
            format!("{batch}"),
            format!("{:.1}", fused.total_us()),
            format!("{:.1}", per.total_us()),
            format!("{:.1}", per.launch_us - fused.launch_us),
            format!("{fused_tput:.1}"),
            format!("{per_tput:.1}"),
        ]);
    }
    t4.print();

    // Part 5: real coordinator oversubscription mini-run (CPU PJRT),
    // recompute preemption vs suspend-to-host swap
    let artifacts = format!("{}/model_config.json", thinkv::model::default_artifacts_dir());
    let mut j = t.to_json();
    j.set("saturation", t2.to_json());
    j.set("swap_vs_recompute", t3.to_json());
    j.set("launch_amortization", t4.to_json());
    if std::path::Path::new(&artifacts).exists()
        && std::env::var("THINKV_BENCH_REAL").map(|v| v == "1").unwrap_or(true)
    {
        use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig, Session};
        let manifest =
            thinkv::model::Manifest::load(&thinkv::model::default_artifacts_dir()).unwrap();
        let base = ServeConfig {
            mode: CompressionMode::thinkv_default(),
            budget: 128,
            max_new_tokens: 24,
            workers: 2,
            temperature: 0.0,
            ..ServeConfig::default()
        };
        let probe = Session::new(0, vec![1, 2, 3], &base, &manifest).unwrap();
        let per = probe.admission_bytes();
        let mut t5 = Table::new(
            "Real coordinator oversubscription (CPU PJRT, pool = 2.5 admissions): swap vs recompute",
            &[
                "requests", "policy", "completed", "wall_s", "preempts", "swap_ins",
                "replayed_steps", "peak_B", "fused_steps", "avg_batch",
            ],
        );
        for requests in [2usize, 8] {
            for swap in [None, Some(256u64 << 20)] {
                let cfg = ServeConfig {
                    pool_bytes: Some(per * 5 / 2),
                    swap_bytes: swap,
                    ..base.clone()
                };
                let c = Coordinator::start(cfg).unwrap();
                let prompts: Vec<Vec<i32>> = (0..requests)
                    .map(|u| (0..64).map(|i| ((i * 3 + u) % 512) as i32).collect())
                    .collect();
                let t0 = std::time::Instant::now();
                let rs = c.run_batch(prompts).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                let s = c.sched_stats();
                assert!(s.pool_peak <= s.pool_capacity, "pool overflow");
                // decode steps beyond the tokens delivered = replay waste
                let replayed: u64 = rs
                    .iter()
                    .map(|r| r.breakdown.steps.saturating_sub(r.tokens.len() as u64))
                    .sum();
                if swap.is_some() {
                    assert_eq!(replayed, 0, "swapped sessions must not replay");
                }
                // every decode step goes through the fused entry point,
                // even when the batch happens to hold one session
                assert!(s.fused_steps > 0, "no fused decode steps recorded");
                t5.row(&[
                    format!("{requests}"),
                    if swap.is_some() { "swap" } else { "recompute" }.to_string(),
                    format!("{}", rs.iter().filter(|r| r.error.is_none()).count()),
                    format!("{wall:.2}"),
                    format!("{}", s.preemptions),
                    format!("{}", s.swap_ins),
                    format!("{replayed}"),
                    format!("{}", s.pool_peak),
                    format!("{}", s.fused_steps),
                    format!("{:.2}", s.fused_sessions as f64 / s.fused_steps.max(1) as f64),
                ]);
            }
        }
        t5.print();
        j.set("real_oversubscription", t5.to_json());
    }
    write_results("scheduler_saturation", j);
    println!("\nExpected shape: FullKV admits ~13 requests on A100 while ThinKV admits\nhundreds; past saturation the scheduler queues instead of overflowing, and\nthe real run completes every request with pool.peak() <= capacity. In the\nswap-vs-recompute sweep ThinKV's suspend-to-host round trip is orders of\nmagnitude cheaper than replaying the CoT (and the real swap run finishes\nwith zero replayed steps), while FullKV must move GBs per preemption. The\nlaunch-amortization sweep shows fused-step throughput rising with decode\nbatch size: one fused call per step beats N per-session launches (the\nTables 2/3 large-batch regime).");
}
