//! Appendix reproductions: Table 8 (GSM8K/MobileLLM), Table 10 (data
//! formats), Table 11 (LLM generalization, |T|=1), Fig 17 (Pareto front),
//! Table 12 (vLLM-integrated iso-batch throughput), Table 14 (TPR +
//! Intelligence/Watt).

use thinkv::bench::{bench_len_scale, bench_seeds, write_results, Table};
use thinkv::quant::Precision;
use thinkv::sim::harness::{EvictKind, Method, SimConfig, ThinKvSim};
use thinkv::sim::oracle::{fidelity, fidelity_int};
use thinkv::sim::{run_method, DatasetProfile, GpuProfile, LrmProfile, ServingCost, Trace};

fn avg_pass1(ds: &DatasetProfile, m: &Method, budget: usize, scale: f64) -> (f64, f64) {
    let seeds = bench_seeds();
    let (mut a, mut mem) = (0.0, 0.0);
    for &s in &seeds {
        let t = Trace::generate(ds, s, scale);
        let r = run_method(&t, m, &SimConfig { budget, seed: s, stride: 4, rollouts: 24 });
        a += r.pass1;
        mem += r.mem_frac;
    }
    let n = seeds.len() as f64;
    (a / n, mem / n)
}

fn main() {
    let scale = bench_len_scale();

    // Table 8: MobileLLM-R1-950M on GSM8K, k=256
    let gsm = DatasetProfile::gsm8k();
    let mut t8 = Table::new("Table 8 (E.6): GSM8K, MobileLLM-R1-950M profile, k=256", &["method", "compression_x", "acc"]);
    let (a, m) = avg_pass1(&gsm, &Method::FullKv, usize::MAX, scale);
    t8.row(&["FullKV".into(), format!("{:.0}", 1.0 / m.max(1e-9)), format!("{:.1}", a * 100.0)]);
    let (a, m) = avg_pass1(&gsm, &Method::Evict(EvictKind::Rkv), 256, scale);
    t8.row(&["R-KV".into(), format!("{:.0}", 1.0 / m), format!("{:.1}", a * 100.0)]);
    let (a, m) = avg_pass1(&gsm, &Method::ThinKv(ThinKvSim::default()), 256, scale);
    t8.row(&["ThinKV".into(), format!("{:.0}", 1.0 / m), format!("{:.1}", a * 100.0)]);
    t8.print();

    // Table 10: NVFP4/ternary vs INT4/INT2 element formats
    let mut t10 = Table::new("Table 10 (E.8): data-format fidelity", &["format", "fidelity"]);
    t10.row(&["NVFP4".into(), format!("{:.3}", fidelity(Some(Precision::Nvfp4)))]);
    t10.row(&["INT4".into(), format!("{:.3}", fidelity_int(4))]);
    t10.row(&["Ternary(+E4M3 scale)".into(), format!("{:.3}", fidelity(Some(Precision::Ternary)))]);
    t10.row(&["INT2".into(), format!("{:.3}", fidelity_int(2))]);
    t10.print();

    // Table 11: LLM generalization (LongWriter, |T| = 1)
    let lw = DatasetProfile::longwriter();
    let mut t11 = Table::new("Table 11 (E.10): LLM long-response generalization (|T|=1)", &["method", "acc", "mem_%"]);
    let (a, _) = avg_pass1(&lw, &Method::FullKv, usize::MAX, scale);
    t11.row(&["FullKV".into(), format!("{:.1}", a * 100.0), "100".into()]);
    let (a, m) = avg_pass1(&lw, &Method::Evict(EvictKind::H2O), 300, scale);
    t11.row(&["H2O (5%)".into(), format!("{:.1}", a * 100.0), format!("{:.1}", m * 100.0)]);
    let tk1 = ThinKvSim { n_thoughts: 1, thresholds: vec![], ..Default::default() };
    let (a, m) = avg_pass1(&lw, &Method::ThinKv(tk1), 300, scale);
    t11.row(&["ThinKV".into(), format!("{:.1}", a * 100.0), format!("{:.1}", m * 100.0)]);
    t11.print();

    // Fig 17: Pareto front — accuracy vs KV size across config sweeps
    let aime = DatasetProfile::aime();
    let mut f17 = Table::new("Fig 17 (E.11): Pareto sweep, acc vs mem (AIME)", &["method", "config", "mem_%", "acc"]);
    for b in [256usize, 1024, 4096] {
        let (a, m) = avg_pass1(&aime, &Method::ThinKv(ThinKvSim::default()), b, scale);
        f17.row(&["ThinKV".into(), format!("k={b}"), format!("{:.2}", m * 100.0), format!("{:.1}", a * 100.0)]);
        let (a, m) = avg_pass1(&aime, &Method::Evict(EvictKind::Rkv), b, scale);
        f17.row(&["R-KV".into(), format!("k={b}"), format!("{:.2}", m * 100.0), format!("{:.1}", a * 100.0)]);
    }
    let (a, m) = avg_pass1(&aime, &Method::Kivi { prec: Precision::Ternary }, usize::MAX, scale);
    f17.row(&["KIVI-2".into(), "-".into(), format!("{:.2}", m * 100.0), format!("{:.1}", a * 100.0)]);
    f17.print();

    // Table 12: vLLM-integrated iso-batch throughput (cost model at B=8/256)
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b());
    let mut t12 = Table::new("Table 12 (E.12): iso-batch throughput in the serving stack", &["method", "batch", "tok_s"]);
    for batch in [8usize, 256] {
        let full = cost.decode_step(batch, cost.model.fullkv_bytes_per_token() * 16_384.0, 0.0, false, 0.0);
        if batch == 8 {
            t12.row(&["FullKV".into(), format!("{batch}"), format!("{:.1}", cost.throughput_tok_s(batch, &full))]);
        }
        let kv16 = cost.model.kv_bytes_per_token(16.0) * 1024.0;
        let ovl = cost.decode_step(batch, kv16, kv16 * 0.05, true, 1.0);
        t12.row(&["R-KV (ovl)".into(), format!("{batch}"), format!("{:.1}", cost.throughput_tok_s(batch, &ovl))]);
        let tk = cost.decode_step(batch, cost.model.kv_bytes_per_token(3.4) * 1024.0, 0.0, false, 2.0);
        t12.row(&["ThinKV".into(), format!("{batch}"), format!("{:.1}", cost.throughput_tok_s(batch, &tk))]);
    }
    t12.print();

    // Table 14: time-per-request + Intelligence/Watt
    let mut t14 = Table::new("Table 14 (E.15): TPR + Intelligence/Watt (AIME, R1-8B profile)", &["method", "budget", "TPR_s", "acc", "intel_per_watt"]);
    let gen = 9020.0;
    let watt = 400.0; // A100 board power
    for (name, kv_bits, budget, gather, m) in [
        ("FullKV", 16.0, usize::MAX, false, Method::FullKv),
        ("R-KV (seq)", 16.0, 1024, true, Method::Evict(EvictKind::Rkv)),
        ("ThinKV", 3.4, 1024, false, Method::ThinKv(ThinKvSim::default())),
    ] {
        let live = if budget == usize::MAX { gen / 2.0 } else { budget as f64 };
        let kv = cost.model.kv_bytes_per_token(kv_bits) * live;
        let g = if gather { kv * 0.05 } else { 0.0 };
        let step = cost.decode_step(8, kv, g, false, 0.0);
        let tpr = step.total_us() * gen / 1e6;
        let (a, _) = avg_pass1(&aime, &m, budget, scale);
        // intelligence/watt: accuracy per joule-second normalized
        let ipw = a * 100.0 / (tpr * watt) * 100.0;
        t14.row(&[name.into(), if budget == usize::MAX { "-".into() } else { budget.to_string() },
                  format!("{:.1}", tpr), format!("{:.1}", a * 100.0), format!("{:.2}", ipw)]);
    }
    t14.print();

    let mut j = t8.to_json();
    j.set("table10", t10.to_json());
    j.set("table11", t11.to_json());
    j.set("fig17", f17.to_json());
    j.set("table12", t12.to_json());
    j.set("table14", t14.to_json());
    write_results("appendix", j);
}
