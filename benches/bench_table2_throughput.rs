//! Table 2: end-to-end throughput on A100-80GB and GH200 (cost model),
//! R1-Llama-8B, 32K-token continuous generation, including the iso-batch
//! iso-compression comparison.

use thinkv::bench::{write_results, Table};
use thinkv::sim::{GpuProfile, LrmProfile, ServingCost};

fn row(
    t: &mut Table,
    name: &str,
    budget: Option<usize>,
    mem_pct: f64,
    entries: &[(usize, f64)],
) {
    let mut cells = vec![
        name.to_string(),
        budget.map(|b| b.to_string()).unwrap_or("-".into()),
        format!("{:.2}", mem_pct),
    ];
    for (b, tok) in entries {
        cells.push(format!("{b}"));
        cells.push(format!("{:.1}", tok));
    }
    t.row(&cells);
}

fn main() {
    let model = LrmProfile::r1_llama_8b();
    let gen = 32_768.0;
    let fullkv_bytes = model.fullkv_bytes_per_token() * gen;
    let mut t = Table::new(
        "Table 2: throughput (tok/s), R1-Llama-8B, 32K generation",
        &["method", "budget", "mem_%", "A100_batch", "A100_tok_s", "GH200_batch", "GH200_tok_s"],
    );
    let configs: Vec<(&str, Option<usize>, f64, f64, bool, f64)> = vec![
        // (name, budget, kv_bytes/req, gather_bytes/req, overlapped, overhead_us)
        ("FullKV", None, fullkv_bytes / 2.0, 0.0, false, 0.0),
        // R-KV gathers on ~83% of steps; amortized rewrite traffic is a
        // fraction of the live cache per step (Table 5: gather ~= 0.6x
        // attention time)
        ("R-KV (seq)", Some(1024), model.kv_bytes_per_token(16.0) * 1024.0,
         model.kv_bytes_per_token(16.0) * 1024.0 * 0.05, false, 1.0),
        ("R-KV (ovl)", Some(1024), model.kv_bytes_per_token(16.0) * 1024.0,
         model.kv_bytes_per_token(16.0) * 1024.0 * 0.05, true, 1.0),
        ("ThinKV", Some(1024), model.kv_bytes_per_token(3.4) * 1024.0, 0.0, false, 2.0),
    ];
    for (name, budget, kv, gather, ovl, oh) in &configs {
        let mut entries = Vec::new();
        let mut mem_pct = 0.0;
        for gpu in [GpuProfile::a100_80gb(), GpuProfile::gh200()] {
            let cost = ServingCost::new(gpu, model.clone());
            // FullKV cache grows: size at steady state ~ gen/2 used for batch,
            // but peak (admission) uses full gen
            let admission = if budget.is_none() { fullkv_bytes } else { *kv };
            let batch = cost.max_batch(admission).max(1);
            let step = cost.decode_step(batch, *kv, *gather, *ovl, *oh);
            entries.push((batch, cost.throughput_tok_s(batch, &step)));
            mem_pct = admission / fullkv_bytes * 100.0;
        }
        row(&mut t, name, *budget, mem_pct, &entries);
    }
    t.print();

    // iso-batch, iso-compression comparison at batch 256
    let mut t2 = Table::new(
        "Table 2 (cont.): iso-batch (256) iso-compression comparison",
        &["method", "budget", "mem_%", "A100_tok_s", "GH200_tok_s"],
    );
    let iso: Vec<(&str, f64, f64, bool, f64, f64)> = vec![
        ("R-KV (seq)", model.kv_bytes_per_token(16.0) * 1024.0,
         model.kv_bytes_per_token(16.0) * 1024.0 * 0.05, false, 1.0, 5.48),
        ("R-KV (ovl)", model.kv_bytes_per_token(16.0) * 1024.0,
         model.kv_bytes_per_token(16.0) * 1024.0 * 0.05, true, 1.0, 5.48),
        // ThinKV w/o TBQ: same token budget, fp16 storage, but CT (no gather)
        ("ThinKV w/o TBQ", model.kv_bytes_per_token(16.0) * 1024.0 * 1.055,
         0.0, false, 2.0, 5.78),
    ];
    for (name, kv, gather, ovl, oh, mem) in iso {
        let mut cells = vec![name.to_string(), "1024".to_string(), format!("{mem:.2}")];
        for gpu in [GpuProfile::a100_80gb(), GpuProfile::gh200()] {
            let cost = ServingCost::new(gpu, model.clone());
            let step = cost.decode_step(256, kv, gather, ovl, oh);
            cells.push(format!("{:.1}", cost.throughput_tok_s(256, &step)));
        }
        t2.row(&cells);
    }
    t2.print();
    let mut j = t.to_json();
    j.set("iso_batch", t2.to_json());
    write_results("table2_throughput", j);
    println!("\nExpected shape (paper Table 2): FullKV batch ~13 @ ~300 tok/s on A100;\nThinKV sustains ~3x R-KV's batch and up to ~5.8x R-KV(seq) / ~3.6x R-KV(ovl)\nthroughput; iso-batch iso-compression still ~3.2x/1.6x from CT alone.");
}
