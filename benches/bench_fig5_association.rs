//! Figure 5: pairwise thought associations — influence of segment Y_i on
//! later segments Y_j decays with every intervening transition (Obs 3).

use thinkv::bench::{write_results, Table};
use thinkv::sim::{DatasetProfile, Trace};

fn main() {
    let trace = Trace::generate(&DatasetProfile::aime(), 21, 0.25);
    let n = trace.segments.len().min(10);
    println!("pairwise association matrix (rows=source i, cols=target j, first {n} segments):");
    print!("      ");
    for j in 0..n {
        print!(" {}{:<3}", trace.segments[j].thought.letter(), j);
    }
    println!();
    let mut decay_by_hops: std::collections::BTreeMap<usize, (f64, usize)> = Default::default();
    for i in 0..n {
        let si = &trace.segments[i];
        print!("  {}{:<3}", si.thought.letter(), i);
        for j in 0..n {
            if j <= i {
                print!("    -");
                continue;
            }
            let sj = &trace.segments[j];
            let probe = (sj.start + sj.len / 2).min(trace.total_len() - 1);
            let w: f64 = (si.start..si.end().min(probe))
                .map(|p| trace.attn_weight(probe, p))
                .sum::<f64>()
                / si.len as f64;
            let hops = trace.transitions_between(si.id, probe);
            let e = decay_by_hops.entry(hops).or_insert((0.0, 0));
            e.0 += w;
            e.1 += 1;
            print!(" {:4.2}", w);
        }
        println!();
    }
    let mut t = Table::new(
        "Figure 5: association strength vs transitions elapsed",
        &["transitions_between", "mean_association", "pairs"],
    );
    for (hops, (sum, cnt)) in &decay_by_hops {
        t.row(&[format!("{hops}"), format!("{:.3}", sum / *cnt as f64), format!("{cnt}")]);
    }
    t.print();
    write_results("fig5_association", t.to_json());
    println!("\nExpected shape (paper Obs 3): association decreases monotonically with the\nnumber of intervening transition thoughts.");
}
