//! Figure 7: the cost of gather-based compaction. (a) sequential gather
//! overhead grows with batch (up to ~37x TPOT slowdown); (b) overlapped
//! gather hides at small batch but contends on HBM at large batch
//! (Obs 4a/4b). Cost-model numbers plus a real CPU gather measurement.

use thinkv::bench::{write_results, Table};
use thinkv::kvcache::Fp32Cache;
use thinkv::sim::{GpuProfile, LrmProfile, ServingCost};

fn main() {
    let cost = ServingCost::new(GpuProfile::a100_80gb(), LrmProfile::r1_llama_8b());
    let budget = 1024.0;
    let kv = cost.model.kv_bytes_per_token(16.0) * budget;
    // R-KV evicts ~every step once saturated; compaction rewrites the live cache
    let gather = kv; // bytes rewritten per eviction event
    let mut t = Table::new(
        "Figure 7: gather overhead vs batch (R-KV, 1024-token budget, A100 profile)",
        &["batch", "tpot_none_ms", "tpot_seq_ms", "seq_slowdown_x", "tpot_ovl_ms", "attn_inflation_%"],
    );
    for batch in [1usize, 8, 32, 64, 128, 256] {
        let none = cost.decode_step(batch, kv, 0.0, false, 0.0);
        let seq = cost.decode_step(batch, kv, gather, false, 0.0);
        let ovl = cost.decode_step(batch, kv, gather, true, 0.0);
        t.row(&[
            format!("{batch}"),
            format!("{:.3}", cost.tpot_ms(&none)),
            format!("{:.3}", cost.tpot_ms(&seq)),
            format!("{:.2}", seq.total_us() / none.total_us()),
            format!("{:.3}", cost.tpot_ms(&ovl)),
            format!("{:.1}", (ovl.attention_us / none.attention_us - 1.0) * 100.0),
        ]);
    }
    t.print();

    // real CPU gather microbenchmark (the actual data movement)
    let mut t2 = Table::new(
        "Real gather kernel (CPU, Fp32Cache::compact_gather)",
        &["capacity", "evicted", "bytes_moved", "time_us"],
    );
    for cap in [512usize, 2048, 8192] {
        let mut c = Fp32Cache::new(32, cap, 2 * 8 * 128 / 8, 16);
        let k = vec![1.0f32; 32 * cap * c.kv_dim];
        c.write_prefill(&k.clone(), &k, cap.min(c.capacity));
        let evict: Vec<usize> = (0..cap).step_by(2).collect();
        c.evict_positions(&evict);
        c.compact_gather();
        t2.row(&[
            format!("{cap}"),
            format!("{}", evict.len()),
            format!("{}", c.gather_bytes),
            format!("{:.1}", c.gather_nanos as f64 / 1e3),
        ]);
    }
    t2.print();
    let mut j = t.to_json();
    j.set("real_gather", t2.to_json());
    write_results("fig7_gather", j);
    println!("\nExpected shape (paper Obs 4): sequential gather slowdown grows sharply with\nbatch; overlapped gather helps but inflates attention up to ~35% via HBM\ncontention. ThinKV's CT does zero gather.");
}
