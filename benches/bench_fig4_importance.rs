//! Figure 4: counterfactual thought importance — KL-style damage from
//! removing each thought category, averaged over rollouts (Obs 2: R>E>T,
//! with outlier high-importance transition anchors).

use thinkv::bench::{bench_seeds, write_results, Table};
use thinkv::kvcache::Thought;
use thinkv::sim::oracle::{Oracle, RetentionRecord};
use thinkv::sim::{DatasetProfile, Trace};

fn damage_for(trace: &Trace, pred: &dyn Fn(&thinkv::sim::TraceSegment) -> bool) -> f64 {
    let recs: Vec<RetentionRecord> = trace
        .segments
        .iter()
        .map(|s| RetentionRecord {
            seg: s.id,
            kept_info_fid: if pred(s) { 0.0 } else { 1.0 },
            min_kept_count: if pred(s) { 0 } else { s.len },
            importance: s.importance,
            anchor: s.anchor,
        })
        .collect();
    let o = Oracle { rollouts: 64, ..Oracle::default() };
    let full: Vec<RetentionRecord> = trace
        .segments
        .iter()
        .map(|s| RetentionRecord {
            seg: s.id,
            kept_info_fid: 1.0,
            min_kept_count: s.len,
            importance: s.importance,
            anchor: s.anchor,
        })
        .collect();
    let base = o.evaluate(trace, &full, 0.0, 1).p_correct;
    let hit = o.evaluate(trace, &recs, 0.0, 1).p_correct;
    (base - hit).max(0.0) / base.max(1e-9)
}

fn main() {
    let mut t = Table::new(
        "Figure 4: counterfactual thought importance (GPT-OSS-20B profile)",
        &["dataset", "drop_R", "drop_E", "drop_T_nonanchor", "drop_T_anchor"],
    );
    for ds in [DatasetProfile::aime(), DatasetProfile::livecodebench()] {
        let (mut r, mut e, mut tn, mut ta) = (0.0, 0.0, 0.0, 0.0);
        let mut ta_n = 0usize;
        let seeds = bench_seeds();
        for &s in &seeds {
            let trace = Trace::generate(&ds, s, 0.3);
            r += damage_for(&trace, &|x| x.thought == Thought::Reasoning && x.id > 0);
            e += damage_for(&trace, &|x| x.thought == Thought::Execution);
            tn += damage_for(&trace, &|x| x.thought == Thought::Transition && !x.anchor);
            if trace.segments.iter().any(|x| x.anchor) {
                ta += damage_for(&trace, &|x| x.anchor);
                ta_n += 1;
            }
        }
        let n = seeds.len() as f64;
        t.row(&[
            ds.name.to_string(),
            format!("{:.3}", r / n),
            format!("{:.3}", e / n),
            format!("{:.3}", tn / n),
            if ta_n > 0 { format!("{:.3}", ta / ta_n as f64) } else { "n/a".into() },
        ]);
    }
    t.print();
    write_results("fig4_importance", t.to_json());
    println!("\nExpected shape (paper Obs 2): R > E > T for regular segments; anchor\ntransitions are outliers with catastrophic importance (endless loops).");
}
