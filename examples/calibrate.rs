//! Offline thought-decomposition calibration (paper §4.1, Algorithm 1).
//!
//! Runs the KDE pipeline end-to-end: collect per-layer attention-sparsity
//! series on a calibration set (simulated traces shaped like Figure 3,
//! plus — if artifacts exist — a short *real* run of the PJRT model with
//! sparsity measured from the fused kernel's attention rows), then select
//! the optimal layer subset L* and thresholds Θ.

use thinkv::sim::{DatasetProfile, Trace};
use thinkv::thought::{calibrate, Kde};
use thinkv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("ThinKV calibration (KDE over attention sparsity)\n");

    // --- simulated calibration set (100-prompt analogue of s1K sampling) --
    let prompts = 12;
    let layers = 8;
    let mut rng = Rng::new(5);
    let mut series = Vec::new();
    for p in 0..prompts {
        let trace = Trace::generate(&DatasetProfile::aime(), 900 + p as u64, 0.3);
        let mut per_layer = Vec::new();
        for l in 0..layers {
            // even layers: ambiguous/unimodal (like GPT-OSS layers in §E.4);
            // odd layers: clean tri-modal structure
            let clean = l % 2 == 1;
            let samples: Vec<f64> = trace.sparsity[trace.prompt_len..]
                .iter()
                .map(|&s| if clean { s } else { (0.5 + rng.normal() * 0.05).clamp(0.0, 1.0) })
                .collect();
            per_layer.push(samples);
        }
        series.push(per_layer);
    }

    // per-layer KDE mode counts for the first prompt (Fig 3-style readout)
    println!("layer KDE mode counts (prompt 0):");
    for (l, samples) in series[0].iter().enumerate() {
        let kde = Kde::fit(samples, 256, 1e-3);
        let modes = kde.mode_positions(0.12);
        println!(
            "  layer {l}: {} mode(s) at {:?}",
            modes.len(),
            modes.iter().map(|m| (m * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
    }

    let result = calibrate(&series, 3, 4, 0.12);
    println!("\nselected L* = {:?} (votes {:?})", result.layers, result.votes);
    println!(
        "thresholds Θ = [{:.3}, {:.3}]  (sparsity regimes: E < {:.2} < R < {:.2} < T)",
        result.thresholds[0], result.thresholds[1], result.thresholds[0], result.thresholds[1]
    );

    // --- real-model sparsity probe (optional, needs artifacts) -----------
    let dir = thinkv::model::default_artifacts_dir();
    if std::path::Path::new(&format!("{dir}/model_config.json")).exists() {
        use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
        println!("\nreal-model probe: decoding 64 tokens and measuring sparsity...");
        let cfg = ServeConfig {
            mode: CompressionMode::thinkv_default(),
            budget: 512,
            max_new_tokens: 64,
            workers: 1,
            ..ServeConfig::default()
        };
        let coordinator = Coordinator::start(cfg)?;
        let prompt: Vec<i32> = (0..64).map(|i| (i * 7 % 512) as i32).collect();
        let r = coordinator.submit(prompt)?.wait()?;
        println!(
            "  decoded {} tokens at {:.2} bits avg precision (classifier ran {} refreshes)",
            r.tokens.len(),
            r.avg_bits,
            r.breakdown.refresh_calls
        );
    } else {
        println!("\n(artifacts not built; skipping the real-model probe)");
    }
    println!("\ncalibration OK");
    Ok(())
}
