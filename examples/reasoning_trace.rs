//! Reasoning-trace walkthrough: the paper's Figure 6 / Figure 10(b)
//! mechanics on a simulated AIME-style chain of thought.
//!
//! Shows, segment by segment: the classifier's thought labels vs ground
//! truth, TBQ precision assignment, TBE annealing at transitions (the
//! sawtooth eviction curve), the CT block table state with slot reuse, and
//! the final accuracy verdict from the counterfactual oracle.

use thinkv::compress::tbe::{Tbe, TbeConfig};
use thinkv::kvcache::{CacheConfig, CtCache, Thought};
use thinkv::quant::Precision;
use thinkv::sim::harness::{run_method, Method, SimConfig, ThinKvSim};
use thinkv::sim::{DatasetProfile, Trace};
use thinkv::util::rng::Rng;

fn main() {
    println!("ThinKV reasoning-trace walkthrough\n");
    let dataset = DatasetProfile::aime();
    let trace = Trace::generate(&dataset, 4242, 0.3);
    println!(
        "simulated {} CoT: {} tokens, {} thought segments, breakdown R/E/T = {:.0}%/{:.0}%/{:.0}%",
        dataset.name,
        trace.gen_len,
        trace.segments.len(),
        trace.thought_breakdown()[0],
        trace.thought_breakdown()[1],
        trace.thought_breakdown()[2],
    );

    // --- drive a real CtCache + TBE over the trace (Fig 10b sawtooth) ----
    let cfg = CacheConfig {
        layers: 2,
        capacity: 2048,
        block_size: 8,
        hkv: 2,
        dh: 32,
        buf_slots: 16,
    };
    let mut cache = CtCache::new(cfg.clone());
    let mut tbe = Tbe::new(TbeConfig::new(1024));
    let mut rng = Rng::new(1);
    let psi = |t: Thought| match t {
        Thought::Transition => Precision::Ternary,
        _ => Precision::Nvfp4,
    };
    println!("\nsegment timeline (budget 1024, schedule R={:?}):", tbe.cfg.retention);
    let mut curve = Vec::new();
    for seg in trace.segments.iter().skip(1).take(14) {
        let sid = cache.open_segment(seg.thought, seg.start);
        for i in 0..seg.len.min(160) {
            let n = cfg.layers * cfg.kv_dim();
            let mut k = vec![0f32; n];
            let mut v = vec![0f32; n];
            rng.fill_normal_f32(&mut k, 0.0, 1.0);
            rng.fill_normal_f32(&mut v, 0.0, 1.0);
            if cache.push_token(&k, &v, seg.start + i, sid, seg.thought) {
                while cache.flush_buffer(&psi).is_err() {
                    tbe.ensure_budget(&mut cache);
                }
            }
        }
        if seg.thought == Thought::Transition {
            tbe.on_transition_end(&mut cache, sid);
        }
        tbe.ensure_budget(&mut cache);
        curve.push(cache.live_tokens());
        println!(
            "  seg {:2} [{}] pos {:5}..{:5}  live-after={:5}  reuses={:3}  evicted-total={}",
            seg.id,
            seg.thought.letter(),
            seg.start,
            seg.end(),
            cache.live_tokens(),
            cache.tables[0].reuse_count,
            tbe.stats.tokens_evicted,
        );
    }
    println!("\neviction curve (live tokens after each segment): {curve:?}");
    println!(
        "TBE stats: anneals={}, case1={}, case2={}, tokens evicted={}",
        tbe.stats.anneal_calls, tbe.stats.case1_events, tbe.stats.case2_events, tbe.stats.tokens_evicted
    );

    // CT block table peek
    let t0 = &cache.tables[0];
    println!(
        "\nCT block table (layer 0): {} blocks allocated, {} in-place reuses, {} free",
        t0.allocated_blocks(),
        t0.reuse_count,
        t0.free_blocks_left()
    );
    for b in t0.blocks.iter().take(5) {
        println!(
            "  block {:3} [{}] filled {}/{} evict_mask {:08b} segments {:?}",
            b.phys,
            b.thought.letter(),
            b.filled,
            t0.block_size,
            b.eviction_mask,
            b.start_indices
        );
    }

    // --- full harness comparison on the same trace -----------------------
    println!("\naccuracy verdicts (oracle, budget 512):");
    let sim_cfg = SimConfig { budget: 512, seed: 9, stride: 4, rollouts: 128 };
    for m in [
        Method::FullKv,
        Method::ThinKv(ThinKvSim::default()),
        Method::Evict(thinkv::sim::harness::EvictKind::Rkv),
        Method::Kivi { prec: Precision::Ternary },
    ] {
        let r = run_method(&trace, &m, &sim_cfg);
        println!(
            "  {:16} pass@1 {:.3}  mem {:5.2}%  bits {:4.1}  recall@10 {:.2}  inflation {:.2}x",
            r.method, r.pass1, r.mem_frac * 100.0, r.avg_bits, r.recall10, r.len_inflation
        );
    }
    println!("\nreasoning_trace OK");
}
