//! Quickstart: load the AOT artifacts, start the ThinKV coordinator, and
//! generate a few sequences — the 60-second tour of the system.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What it demonstrates: prefill -> quantized paged decode (fused Pallas
//! kernel via PJRT) -> thought classification -> TBQ precision assignment
//! -> TBE annealing under a 256-token budget, with CT slot reuse.

use thinkv::coordinator::{CompressionMode, Coordinator, ServeConfig};
use thinkv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("ThinKV quickstart — thought-adaptive KV cache compression\n");

    let cfg = ServeConfig {
        mode: CompressionMode::thinkv_default(),
        budget: 256,
        max_new_tokens: 160,
        workers: 2,
        temperature: 0.8,
        ..ServeConfig::default()
    };
    println!("starting coordinator: mode={}, budget={} tokens", cfg.mode.label(), cfg.budget);
    let coordinator = Coordinator::start(cfg)?;

    let mut rng = Rng::new(2024);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..64).map(|_| rng.below(512) as i32).collect())
        .collect();

    let t0 = std::time::Instant::now();
    let results = coordinator.run_batch(prompts)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nper-request results:");
    for r in &results {
        println!(
            "  req {}: {:3} tokens | ttft {:7.1} ms | tpot {:6.2} ms | avg precision {:.2} bits | live KV {:4} | CT slot reuses {}",
            r.id,
            r.tokens.len(),
            r.ttft_ms,
            r.tpot_ms,
            r.avg_bits,
            r.live_tokens,
            r.ct_reuses
        );
    }
    let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!("\nthroughput: {:.1} tok/s over {} requests", toks as f64 / wall, results.len());

    // memory math vs FullKV
    let avg_bits: f64 =
        results.iter().map(|r| r.avg_bits).sum::<f64>() / results.len() as f64;
    let budget = 256.0f64;
    let total = 64.0 + 160.0;
    let frac = budget.min(total) * avg_bits / (total * 16.0);
    println!(
        "KV memory vs FullKV(fp16): ~{:.1}% (budget {} tokens at {:.2} bits avg)",
        frac * 100.0,
        256,
        avg_bits
    );
    println!("\nquickstart OK");
    Ok(())
}
