//! Multi-user serving demo (the Figure-9 scenario at laptop scale):
//! starts the TCP JSON server with a ThinKV coordinator, then drives B
//! concurrent clients and reports system throughput vs per-user latency.
//!
//!     cargo run --release --example serve -- --users 4 --max-tokens 48

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use thinkv::coordinator::{CompressionMode, ServeConfig};
use thinkv::server::{Client, Server};
use thinkv::util::cli::Args;
use thinkv::util::rng::Rng;
use thinkv::util::stats::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let users = args.usize_or("users", 4);
    let reqs_per_user = args.usize_or("requests", 2);
    let max_tokens = args.usize_or("max-tokens", 48);
    let mode = CompressionMode::parse(&args.str_or("mode", "thinkv"))
        .unwrap_or_else(CompressionMode::thinkv_default);

    println!("ThinKV serving demo: {} users x {} requests, mode={}", users, reqs_per_user, mode.label());
    // --pool-mb caps the KV block pool so oversubscribed runs exercise
    // admission queueing + preemption (0 = unbounded); --swap-mb lets
    // preempted sessions suspend to host instead of recomputing
    let pool_mb = args.u64_or("pool-mb", 0);
    let swap_mb = args.u64_or("swap-mb", 0);
    let cfg = ServeConfig {
        mode,
        budget: args.usize_or("budget", 512),
        max_new_tokens: max_tokens,
        workers: args.usize_or("workers", 2),
        pool_bytes: (pool_mb > 0).then_some(pool_mb << 20),
        swap_bytes: (swap_mb > 0).then_some(swap_mb << 20),
        ..ServeConfig::default()
    };
    let server = Server::start("127.0.0.1:0", cfg)?;
    let addr = server.addr.clone();
    println!("server on {addr}");

    let done = Arc::new(AtomicUsize::new(0));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for u in 0..users {
        let addr = addr.clone();
        let done = Arc::clone(&done);
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut rng = Rng::new(77 + u as u64);
            let mut client = Client::connect(&addr)?;
            let mut latencies = Vec::new();
            for r in 0..reqs_per_user {
                let prompt: Vec<i32> = (0..64).map(|_| rng.below(512) as i32).collect();
                let t = std::time::Instant::now();
                let resp = client.request(&prompt, (u * 100 + r) as u64)?;
                let ms = t.elapsed().as_secs_f64() * 1e3;
                latencies.push(ms);
                done.fetch_add(1, Ordering::SeqCst);
                let toks = resp.get("tokens").and_then(|t| t.as_arr()).map(|a| a.len()).unwrap_or(0);
                println!("  user {u} req {r}: {toks} tokens in {ms:.0} ms");
            }
            Ok(latencies)
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::SeqCst);
    println!("\nsystem throughput: {:.2} reqs/s ({total} requests in {wall:.1}s)", total as f64 / wall);
    println!("user latency: mean {:.0} ms, p50 {:.0} ms, p99 {:.0} ms",
             mean(&all), percentile(&all, 50.0), percentile(&all, 99.0));

    // server stats round-trip (includes pool/scheduler counters)
    let mut c = Client::connect(&addr)?;
    let stats = c.stats()?;
    println!("server stats: {}", stats.to_string());
    if let Some(p) = stats.get("preemptions").and_then(|v| v.as_f64()) {
        println!("scheduler: {} preemptions, pool peak {} B",
                 p, stats.get("pool_peak").and_then(|v| v.as_f64()).unwrap_or(0.0));
    }
    server.shutdown();
    println!("serve demo OK");
    Ok(())
}
