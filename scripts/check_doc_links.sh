#!/bin/sh
# Doc-link check: every relative markdown link in the top-level docs
# must resolve to a real file, so README <-> ARCHITECTURE (and friends)
# cannot silently rot. External (http/https) links and pure #anchors
# are skipped. Run from the repo root: scripts/check_doc_links.sh
set -eu

status=0
for doc in README.md docs/ARCHITECTURE.md ROADMAP.md CHANGES.md; do
    [ -f "$doc" ] || { echo "missing doc: $doc"; status=1; continue; }
    dir=$(dirname "$doc")
    # extract (target) of every markdown [text](target) link
    links=$(grep -oE '\]\([^)]+\)' "$doc" | sed 's/^](//; s/)$//') || true
    for link in $links; do
        case "$link" in
            http://*|https://*|\#*) continue ;;
        esac
        target="$dir/${link%%#*}"
        if [ ! -e "$target" ]; then
            echo "broken link in $doc: $link"
            status=1
        fi
    done
done
[ "$status" -eq 0 ] && echo "doc links OK"
exit "$status"
