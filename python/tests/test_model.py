"""L2 model correctness: decode step vs an incremental pure-numpy transformer.

Builds a numpy re-implementation of the transformer and checks that
(a) prefill matches it, (b) the quantized decode step at FP8 precision with
full retention tracks the fp32 reference closely, (c) the fp32 decode path
with the cache filled from prefill reproduces full causal attention exactly,
and (d) shapes/manifest invariants hold.
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import formats as F
from compile import model as M
from compile.kernels import ref as R

CFG = M.ModelConfig()
WS = M.init_weights(CFG, seed=1234)
WS_NP = [np.asarray(w) for w in WS]


def np_forward_tokens(tokens):
    """Full causal forward over `tokens` with numpy; returns logits for every
    position plus per-layer post-RoPE K/V."""
    cfg = CFG
    specs = dict(zip([n for n, _ in cfg.weight_specs()], WS_NP))
    x = specs["embed"][tokens]
    P = len(tokens)
    rep = cfg.n_heads // cfg.n_kv_heads
    ks, vs = [], []
    for l in range(cfg.n_layers):
        h = R.rmsnorm_ref(x, specs[f"l{l}.ln1"])
        q = (h @ specs[f"l{l}.wq"]).reshape(P, cfg.n_heads, cfg.d_head)
        k = (h @ specs[f"l{l}.wk"]).reshape(P, cfg.n_kv_heads, cfg.d_head)
        v = (h @ specs[f"l{l}.wv"]).reshape(P, cfg.n_kv_heads, cfg.d_head)
        q = np.stack([R.rope_ref(q[i], i, base=cfg.rope_base) for i in range(P)])
        k = np.stack([R.rope_ref(k[i], i, base=cfg.rope_base) for i in range(P)])
        attn = np.zeros((P, cfg.n_heads, cfg.d_head), np.float32)
        for i in range(P):
            kk = k[: i + 1]
            vv = v[: i + 1]
            o, _ = R.paged_attention_fp32_ref(q[i], kk, vv, np.ones(i + 1, np.float32))
            attn[i] = o
        x = x + attn.reshape(P, -1) @ specs[f"l{l}.wo"]
        h2 = R.rmsnorm_ref(x, specs[f"l{l}.ln2"])
        # jax.nn.gelu default is tanh-approx=False? jax.nn.gelu(approximate=True) default.
        g = 0.5 * (h2 @ specs[f"l{l}.w1"]) * (1 + np.tanh(np.sqrt(2 / np.pi) * ((h2 @ specs[f"l{l}.w1"]) + 0.044715 * (h2 @ specs[f"l{l}.w1"]) ** 3)))
        x = x + g @ specs[f"l{l}.w2"]
        ks.append(k)
        vs.append(v)
    xf = R.rmsnorm_ref(x, specs["lnf"])
    return xf @ specs["lm_head"], np.stack(ks), np.stack(vs)


@pytest.fixture(scope="module")
def prefill_out():
    tokens = np.arange(CFG.prefill_len, dtype=np.int32) % CFG.vocab
    fn = jax.jit(functools.partial(M.prefill, CFG))
    logits, k, v, obs = fn(WS, jnp.asarray(tokens))
    return tokens, np.asarray(logits), np.asarray(k), np.asarray(v), np.asarray(obs)


class TestPrefill:
    def test_shapes(self, prefill_out):
        _, logits, k, v, obs = prefill_out
        P = CFG.prefill_len
        assert logits.shape == (CFG.vocab,)
        assert k.shape == (CFG.n_layers, P, CFG.n_kv_heads, CFG.d_head)
        assert v.shape == k.shape
        assert obs.shape == (CFG.n_layers, P)

    def test_matches_numpy_reference(self, prefill_out):
        tokens, logits, k, v, _ = prefill_out
        ref_logits, ref_k, ref_v = np_forward_tokens(tokens)
        np.testing.assert_allclose(k, ref_k.transpose(0, 1, 2, 3), atol=1e-4)
        np.testing.assert_allclose(v, ref_v, atol=1e-4)
        np.testing.assert_allclose(logits, ref_logits[-1], atol=1e-3)

    def test_obs_rows_are_distributions(self, prefill_out):
        *_, obs = prefill_out
        np.testing.assert_allclose(obs.sum(axis=1), 1.0, atol=1e-4)


class TestDecodeFp32:
    def test_decode_continues_prefill_exactly(self, prefill_out):
        """Fill the f32 paged cache from prefill, decode one token, compare
        against the full-sequence numpy forward."""
        tokens, _, k, v, _ = prefill_out
        C = 1024
        L, P = CFG.n_layers, CFG.prefill_len
        k_cache = np.zeros((L, C, CFG.n_kv_heads, CFG.d_head), np.float32)
        v_cache = np.zeros_like(k_cache)
        mask = np.zeros((L, C), np.float32)
        k_cache[:, :P] = k
        v_cache[:, :P] = v
        mask[:, :P] = 1.0
        B = CFG.buf_slots
        buf_k = np.zeros((L, B, CFG.n_kv_heads, CFG.d_head), np.float32)
        buf_v = np.zeros_like(buf_k)
        buf_mask = np.zeros((L, B), np.float32)
        next_tok = np.int32(17)
        fn = jax.jit(functools.partial(M.decode_step_fp32, CFG))
        logits, nk, nv, probs = fn(
            WS, jnp.asarray([next_tok]), jnp.asarray([P], jnp.int32),
            jnp.asarray([0], jnp.int32),
            *map(jnp.asarray, (k_cache, v_cache, mask, buf_k, buf_v, buf_mask)))
        full = np.concatenate([tokens, [next_tok]]).astype(np.int32)
        ref_logits, ref_k, _ = np_forward_tokens(full)
        np.testing.assert_allclose(np.asarray(logits), ref_logits[-1], atol=1e-3)
        np.testing.assert_allclose(np.asarray(nk), ref_k[:, -1], atol=1e-4)
        # probability over the P cache slots + self must sum to 1
        p = np.asarray(probs)
        np.testing.assert_allclose(p.sum(axis=2), 1.0, atol=1e-4)

    def test_quant_path_tracks_fp32(self, prefill_out):
        """FP8-quantize the prefill cache; decode logits stay close to fp32."""
        tokens, _, k, v, _ = prefill_out
        C = 512
        L, P = CFG.n_layers, CFG.prefill_len
        G = CFG.groups
        kc = np.zeros((L, C, CFG.n_kv_heads, CFG.d_head), np.uint8)
        ks = np.zeros((L, C, CFG.n_kv_heads, G), np.float32)
        vc, vs = np.zeros_like(kc), np.zeros_like(ks)
        tags = np.full((L, C), F.TAG_FP8, np.uint8)
        mask = np.zeros((L, C), np.float32)
        for l in range(L):
            for i in range(P):
                kc[l, i], ks[l, i] = R.quant_groups_ref(k[l, i], F.TAG_FP8)
                vc[l, i], vs[l, i] = R.quant_groups_ref(v[l, i], F.TAG_FP8)
        mask[:, :P] = 1.0
        B = CFG.buf_slots
        buf_k = np.zeros((L, B, CFG.n_kv_heads, CFG.d_head), np.float32)
        buf_v = np.zeros_like(buf_k)
        buf_mask = np.zeros((L, B), np.float32)
        fnq = jax.jit(functools.partial(M.decode_step_quant, CFG))
        logits_q, *_ = fnq(
            WS, jnp.asarray([17], jnp.int32), jnp.asarray([P], jnp.int32),
            jnp.asarray([0], jnp.int32),
            *map(jnp.asarray, (kc, ks, vc, vs, tags, mask, buf_k, buf_v, buf_mask)))
        full = np.concatenate([tokens, [17]]).astype(np.int32)
        ref_logits, _, _ = np_forward_tokens(full)
        # top-1 must agree and logits must be close
        assert int(np.argmax(np.asarray(logits_q))) == int(np.argmax(ref_logits[-1]))
        np.testing.assert_allclose(np.asarray(logits_q), ref_logits[-1], atol=0.15)


class TestManifest:
    def test_weight_specs_cover_all(self):
        names = [n for n, _ in CFG.weight_specs()]
        assert len(names) == 2 + 8 * CFG.n_layers + 1
        assert names[0] == "embed" and names[-1] == "lm_head"
        assert len(set(names)) == len(names)

    def test_init_weights_deterministic(self):
        w1 = M.init_weights(CFG, seed=99)
        w2 = M.init_weights(CFG, seed=99)
        for a, b in zip(w1, w2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_buf_slots_equals_group_size(self):
        # B_buf must equal quant group size g (paper §4.2)
        assert CFG.buf_slots == F.GROUP_SIZE
