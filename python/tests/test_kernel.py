"""L1 kernel correctness: Pallas (interpret=True) vs pure-numpy oracle.

This is the CORE correctness signal for the compute hot path: every
quantization format and both attention kernels are swept over shapes,
dtypes of content (scale regimes), mask patterns, and tag mixes with
hypothesis, and asserted allclose (bit-exact for integer codes) against
`compile.kernels.ref`.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import quant as Q
from compile.kernels import ref as R
from compile.kernels import paged_attn as PA

TAGS = (F.TAG_TERNARY, F.TAG_NVFP4, F.TAG_FP8)


def rand(rng, *shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Format tables
# ---------------------------------------------------------------------------

class TestE4M3:
    def test_table_size_and_symmetry(self):
        t = F.E4M3_TABLE
        assert t.shape == (256,)
        # sign symmetry (except the NaN slots which decode to 0)
        for c in range(0x80):
            if (c >> 3) == 0xF and (c & 7) == 7:
                continue
            assert t[c] == -t[c | 0x80]

    def test_extremes(self):
        assert F.E4M3_TABLE[0x7E] == 448.0          # max finite
        assert F.E4M3_TABLE[0x01] == pytest.approx(2.0 ** -9)  # min subnormal
        assert F.E4M3_TABLE[0x00] == 0.0

    def test_encode_roundtrip_on_grid(self):
        # every finite table value encodes to itself
        for c in range(256):
            if (c & 0x7F) >> 3 == 0xF and (c & 7) == 7:
                continue
            v = F.E4M3_TABLE[c]
            if v == 0.0:
                continue
            assert F.E4M3_TABLE[F.e4m3_encode(np.float32(v))] == v

    def test_encode_clips_at_max(self):
        assert abs(F.E4M3_TABLE[F.e4m3_encode(np.float32(1e9))]) == 448.0
        assert abs(F.E4M3_TABLE[F.e4m3_encode(np.float32(-1e9))]) == 448.0

    @given(st.floats(-500, 500, allow_nan=False, width=32))
    @settings(max_examples=200, deadline=None)
    def test_encode_is_nearest(self, x):
        x = np.float32(x)
        got = F.E4M3_TABLE[F.e4m3_encode(x)]
        best = F.E4M3_POS_VALUES[np.argmin(np.abs(F.E4M3_POS_VALUES - min(abs(x), 448.0)))]
        assert abs(abs(got) - best) <= 1e-7

    def test_jnp_encode_matches_numpy(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 256, scale=10.0)
        t = Q.tables_jnp()
        assert np.array_equal(np.asarray(Q.e4m3_encode_jnp(jnp.asarray(x), t)),
                              F.e4m3_encode(x))


class TestNVFP4:
    def test_code_table(self):
        assert list(F.NVFP4_MAG) == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]

    def test_decode_all_codes(self):
        t = Q.tables_jnp()
        codes = jnp.arange(16, dtype=jnp.uint8)
        vals = np.asarray(Q.nvfp4_decode_jnp(codes, t))
        assert np.array_equal(vals[:8], F.NVFP4_MAG)
        assert np.array_equal(vals[8:], -F.NVFP4_MAG)


# ---------------------------------------------------------------------------
# Group quantization kernel vs ref
# ---------------------------------------------------------------------------

class TestGroupQuantize:
    @pytest.mark.parametrize("tag", TAGS)
    @pytest.mark.parametrize("shape", [(8, 16), (8, 64), (16, 128), (32, 32)])
    def test_kernel_matches_ref(self, tag, shape):
        rng = np.random.default_rng(42)
        x = rand(rng, *shape, scale=2.0)
        c_ref, s_ref = R.quant_groups_ref(x, tag)
        c_k, s_k = Q.group_quantize(jnp.asarray(x), tag=tag)
        np.testing.assert_array_equal(np.asarray(c_k), c_ref)
        np.testing.assert_allclose(np.asarray(s_k), s_ref, rtol=0, atol=0)

    @given(
        tag=st.sampled_from(TAGS),
        rows=st.sampled_from([8, 16, 24]),
        dcols=st.sampled_from([16, 32, 64, 128]),
        scale=st.floats(1e-3, 100.0),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_kernel_matches_ref_hypothesis(self, tag, rows, dcols, scale, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, rows, dcols, scale=scale)
        c_ref, s_ref = R.quant_groups_ref(x, tag)
        c_k, s_k = Q.group_quantize(jnp.asarray(x), tag=tag)
        np.testing.assert_array_equal(np.asarray(c_k), c_ref)
        np.testing.assert_array_equal(np.asarray(s_k), s_ref)

    @pytest.mark.parametrize("tag", TAGS)
    def test_zero_input(self, tag):
        x = np.zeros((8, 32), np.float32)
        c, s = Q.group_quantize(jnp.asarray(x), tag=tag)
        deq = R.dequant_groups_ref(np.asarray(c), np.asarray(s), tag)
        np.testing.assert_array_equal(deq, x)

    @pytest.mark.parametrize("tag,max_rel", [(F.TAG_FP8, 0.08), (F.TAG_NVFP4, 0.35)])
    def test_relative_error_bound(self, tag, max_rel):
        rng = np.random.default_rng(3)
        x = rand(rng, 16, 64, scale=1.0)
        c, s = R.quant_groups_ref(x, tag)
        deq = R.dequant_groups_ref(c, s, tag)
        rel = np.abs(deq - x).mean() / np.abs(x).mean()
        assert rel < max_rel

    def test_error_hierarchy_fp8_lt_nvfp4_lt_ternary(self):
        """Quantization error must respect the precision hierarchy (§D.3)."""
        rng = np.random.default_rng(5)
        x = rand(rng, 32, 64)
        errs = {}
        for tag in TAGS:
            c, s = R.quant_groups_ref(x, tag)
            errs[tag] = np.abs(R.dequant_groups_ref(c, s, tag) - x).mean()
        assert errs[F.TAG_FP8] < errs[F.TAG_NVFP4] < errs[F.TAG_TERNARY]


# ---------------------------------------------------------------------------
# Fused paged attention kernel vs ref
# ---------------------------------------------------------------------------

def make_quant_cache(rng, C, Hkv, D, tags):
    G = D // F.GROUP_SIZE
    kf = rand(rng, C, Hkv, D)
    vf = rand(rng, C, Hkv, D)
    kc = np.zeros((C, Hkv, D), np.uint8)
    ks = np.zeros((C, Hkv, G), np.float32)
    vc = np.zeros_like(kc)
    vs = np.zeros_like(ks)
    for i in range(C):
        kc[i], ks[i] = R.quant_groups_ref(kf[i], int(tags[i]))
        vc[i], vs[i] = R.quant_groups_ref(vf[i], int(tags[i]))
    return kc, ks, vc, vs


class TestFusedPagedAttention:
    @pytest.mark.parametrize("C,block", [(64, 64), (128, 64), (256, 64), (128, 32)])
    def test_matches_ref(self, C, block):
        rng = np.random.default_rng(C + block)
        H, Hkv, D, BUF = 4, 2, 32, 16
        q = rand(rng, H, D)
        tags = rng.integers(0, 3, size=C).astype(np.uint8)
        mask = (rng.random(C) < 0.7).astype(np.float32)
        kc, ks, vc, vs = make_quant_cache(rng, C, Hkv, D, tags)
        bk, bv = rand(rng, BUF, Hkv, D), rand(rng, BUF, Hkv, D)
        bm = (rng.random(BUF) < 0.5).astype(np.float32)
        o_ref, p_ref = R.fused_paged_attention_ref(q, kc, ks, vc, vs, tags, mask, bk, bv, bm)
        o_k, p_k = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags, mask, bk, bv, bm)), block=block)
        np.testing.assert_allclose(np.asarray(o_k), o_ref, atol=1e-5)
        np.testing.assert_allclose(np.asarray(p_k), p_ref, atol=1e-5)

    def test_fully_masked_cache_attends_buffer_only(self):
        rng = np.random.default_rng(9)
        H, Hkv, D, C, BUF = 4, 2, 32, 64, 16
        q = rand(rng, H, D)
        tags = np.ones(C, np.uint8)
        mask = np.zeros(C, np.float32)
        kc, ks, vc, vs = make_quant_cache(rng, C, Hkv, D, tags)
        bk, bv = rand(rng, BUF, Hkv, D), rand(rng, BUF, Hkv, D)
        bm = np.zeros(BUF, np.float32)
        bm[0] = 1.0
        o_k, p_k = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags, mask, bk, bv, bm)))
        p = np.asarray(p_k)
        # all probability mass on the single valid buffer slot
        np.testing.assert_allclose(p[:, C], 1.0, atol=1e-6)
        assert np.abs(p[:, :C]).max() == 0.0

    def test_everything_masked_returns_zeros(self):
        rng = np.random.default_rng(10)
        H, Hkv, D, C, BUF = 4, 2, 32, 64, 16
        q = rand(rng, H, D)
        tags = np.zeros(C, np.uint8)
        kc, ks, vc, vs = make_quant_cache(rng, C, Hkv, D, tags)
        o_k, p_k = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags,
                               np.zeros(C, np.float32),
                               np.zeros((BUF, Hkv, D), np.float32),
                               np.zeros((BUF, Hkv, D), np.float32),
                               np.zeros(BUF, np.float32))))
        assert np.abs(np.asarray(o_k)).max() == 0.0

    def test_permutation_invariance(self):
        """Theorem 1: permuting cache slots leaves the output unchanged."""
        rng = np.random.default_rng(11)
        H, Hkv, D, C, BUF = 4, 2, 32, 128, 16
        q = rand(rng, H, D)
        tags = rng.integers(0, 3, size=C).astype(np.uint8)
        mask = (rng.random(C) < 0.8).astype(np.float32)
        kc, ks, vc, vs = make_quant_cache(rng, C, Hkv, D, tags)
        bk, bv = rand(rng, BUF, Hkv, D), rand(rng, BUF, Hkv, D)
        bm = np.ones(BUF, np.float32)
        o1, _ = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags, mask, bk, bv, bm)))
        perm = rng.permutation(C)
        o2, _ = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc[perm], ks[perm], vc[perm], vs[perm],
                               tags[perm], mask[perm], bk, bv, bm)))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)

    @given(
        seed=st.integers(0, 2**31 - 1),
        C=st.sampled_from([64, 128, 192]),
        density=st.floats(0.1, 1.0),
        homogeneous_tag=st.sampled_from([None, 0, 1, 2]),
    )
    @settings(max_examples=15, deadline=None)
    def test_matches_ref_hypothesis(self, seed, C, density, homogeneous_tag):
        rng = np.random.default_rng(seed)
        H, Hkv, D, BUF = 4, 2, 32, 16
        q = rand(rng, H, D)
        if homogeneous_tag is None:
            tags = rng.integers(0, 3, size=C).astype(np.uint8)
        else:
            tags = np.full(C, homogeneous_tag, np.uint8)
        mask = (rng.random(C) < density).astype(np.float32)
        kc, ks, vc, vs = make_quant_cache(rng, C, Hkv, D, tags)
        bk, bv = rand(rng, BUF, Hkv, D), rand(rng, BUF, Hkv, D)
        bm = (rng.random(BUF) < 0.5).astype(np.float32)
        o_ref, p_ref = R.fused_paged_attention_ref(q, kc, ks, vc, vs, tags, mask, bk, bv, bm)
        o_k, p_k = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags, mask, bk, bv, bm)))
        np.testing.assert_allclose(np.asarray(o_k), o_ref, atol=2e-5)
        np.testing.assert_allclose(np.asarray(p_k), p_ref, atol=2e-5)


class TestPagedAttentionFp32:
    @pytest.mark.parametrize("C", [64, 256])
    def test_matches_ref(self, C):
        rng = np.random.default_rng(C)
        H, Hkv, D, BUF = 4, 2, 32, 16
        q = rand(rng, H, D)
        k, v = rand(rng, C, Hkv, D), rand(rng, C, Hkv, D)
        mask = (rng.random(C) < 0.6).astype(np.float32)
        bk, bv = rand(rng, BUF, Hkv, D), rand(rng, BUF, Hkv, D)
        bm = (rng.random(BUF) < 0.5).astype(np.float32)
        o_k, p_k = PA.paged_attention_fp32(*map(jnp.asarray, (q, k, v, mask, bk, bv, bm)))
        o_ref, p_ref = R.paged_attention_fp32_ref(
            q, np.concatenate([k, bk]), np.concatenate([v, bv]), np.concatenate([mask, bm]))
        np.testing.assert_allclose(np.asarray(o_k), o_ref, atol=1e-5)
        np.testing.assert_allclose(np.asarray(p_k), p_ref, atol=1e-5)

    def test_quantized_path_approximates_fp32(self):
        """End-to-end sanity: fused quantized attention ~ fp32 attention."""
        rng = np.random.default_rng(77)
        H, Hkv, D, C, BUF = 4, 2, 32, 128, 16
        q = rand(rng, H, D)
        kf, vf = rand(rng, C, Hkv, D), rand(rng, C, Hkv, D)
        mask = np.ones(C, np.float32)
        tags = np.full(C, F.TAG_FP8, np.uint8)
        kc = np.zeros((C, Hkv, D), np.uint8)
        ks = np.zeros((C, Hkv, D // 16), np.float32)
        vc, vs = np.zeros_like(kc), np.zeros_like(ks)
        for i in range(C):
            kc[i], ks[i] = R.quant_groups_ref(kf[i], F.TAG_FP8)
            vc[i], vs[i] = R.quant_groups_ref(vf[i], F.TAG_FP8)
        z = np.zeros((BUF, Hkv, D), np.float32)
        bm = np.zeros(BUF, np.float32)
        o_q, _ = PA.fused_paged_attention(
            *map(jnp.asarray, (q, kc, ks, vc, vs, tags, mask, z, z, bm)))
        o_f, _ = PA.paged_attention_fp32(*map(jnp.asarray, (q, kf, vf, mask, z, z, bm)))
        np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_f), atol=0.06)
