"""Shared numeric-format definitions for ThinKV quantization.

Single source of truth for the three cache element formats the paper uses
(§4.2, §D.3).  The Rust cache-write path mirrors these tables bit-for-bit
(cross-checked via artifacts/quant_golden.json produced by aot.py):

* FP8 E4M3  (tag=2): 1-4-3, bias 7, no inf, S.1111.111 = NaN, max 448.
  Per-(token, head) fp32 scale (the paper's "per-tensor" at cache-entry
  granularity), itself snapped to the E4M3 grid.
* NVFP4     (tag=1): E2M1 codes {0, .5, 1, 1.5, 2, 3, 4, 6} with a sign bit,
  group size g=16 along d_head, group scale = max|x|/6 on the E4M3 grid.
* Ternary   (tag=0): {-1, 0, +1}, g=16, group scale = mean|x| on the E4M3
  grid (2-bit codes; storage-packing accounted analytically, see DESIGN §4).

Storage layout on the XLA side is uniform u8 per element (low bits carry the
code); *reported* memory uses packed accounting.
"""

from __future__ import annotations

import numpy as np

GROUP_SIZE = 16

TAG_TERNARY = 0
TAG_NVFP4 = 1
TAG_FP8 = 2

# NVFP4 (E2M1) magnitude table; code = sign*8 + magnitude-index.
NVFP4_MAG = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
NVFP4_MAX = 6.0

FP8_MAX = 448.0


def _e4m3_decode_table() -> np.ndarray:
    """256-entry decode table for FP8 E4M3 (OCP variant: no inf, 0x7f/0xff NaN).

    NaN codes are mapped to 0.0 — the encoder never emits them.
    """
    tab = np.zeros(256, dtype=np.float32)
    for code in range(256):
        s = -1.0 if (code & 0x80) else 1.0
        e = (code >> 3) & 0xF
        m = code & 0x7
        if e == 0xF and m == 0x7:
            val = 0.0  # NaN slot, unused by the encoder
        elif e == 0:
            val = (m / 8.0) * 2.0 ** (-6)  # subnormal
        else:
            val = (1.0 + m / 8.0) * 2.0 ** (e - 7)
        tab[code] = s * val
    return tab


E4M3_TABLE = _e4m3_decode_table()

# Sorted non-negative magnitudes (with their codes) for nearest-neighbour
# encoding. 120 finite positive values + zero.
_pos = [(E4M3_TABLE[c], c) for c in range(0x80) if not (c >> 3 == 0xF and (c & 7) == 7)]
_pos.sort()
E4M3_POS_VALUES = np.array([v for v, _ in _pos], dtype=np.float32)
E4M3_POS_CODES = np.array([c for _, c in _pos], dtype=np.uint8)


def e4m3_encode(x: np.ndarray) -> np.ndarray:
    """Round-to-nearest E4M3 encode (numpy reference; ties toward smaller)."""
    x = np.asarray(x, dtype=np.float32)
    mag = np.clip(np.abs(x), 0.0, FP8_MAX)
    idx = np.searchsorted(E4M3_POS_VALUES, mag)
    idx = np.clip(idx, 1, len(E4M3_POS_VALUES) - 1)
    lo = E4M3_POS_VALUES[idx - 1]
    hi = E4M3_POS_VALUES[idx]
    pick_hi = (mag - lo) > (hi - mag)
    idx = np.where(pick_hi, idx, idx - 1)
    code = E4M3_POS_CODES[idx]
    code = np.where(np.signbit(x), code | 0x80, code).astype(np.uint8)
    return code


def e4m3_snap(x: np.ndarray) -> np.ndarray:
    """Snap values onto the E4M3 grid (decode(encode(x)))."""
    return E4M3_TABLE[e4m3_encode(x)]
