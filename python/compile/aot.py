"""AOT lowering: JAX/Pallas -> HLO text artifacts consumed by the Rust L3.

Run once via `make artifacts`.  Emits into `artifacts/`:

  prefill_p64.hlo.txt          prompt prefill (P=64)
  prefill_chunk_p64_n{8,16,32}.hlo.txt    one prompt chunk, full-width view
  decode_quant_c{512,1024,2048}.hlo.txt   ThinKV decode step (fused kernel)
  decode_fp32_c{1024,2048,4096}.hlo.txt   FullKV/eviction-baseline decode step
  decode_quant_c{C}_b{1,2,4,8}.hlo.txt    fused multi-request decode (block
  decode_fp32_c{C}_b{1,2,4,8}.hlo.txt       tables over one shared arena)
  attn_micro_c1024.hlo.txt     standalone fused attention (Rust microbench)
  weights.bin                  seeded model weights (TKVW format)
  model_config.json            dims + artifact + weight-order manifest
  quant_golden.bin             ref quantizer vectors (Rust bit-exact check)
  attn_golden.bin              ref attention vectors (Rust runtime check)

Interchange is HLO **text**, not `.serialize()`: jax>=0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import formats as F
from compile import model as M
from compile.kernels import ref as R

QUANT_CAPS = [512, 1024, 2048]
FP32_CAPS = [1024, 2048, 4096]
# Fused multi-request decode: compiled batch widths (ragged batches pad up
# to the smallest covering width; the member mask zeroes pad lanes).
BATCH_WIDTHS = [1, 2, 4, 8]
# Chunked prefill: compiled chunk lengths (all divide prefill_len).
PREFILL_CHUNK_LENS = [8, 16, 32]
MICRO_C = 1024
GOLDEN_ATTN_C = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # constant arrays as `constant({...})`, which the XLA 0.5.1 text parser
    # silently reconstructs as garbage — the kernel's dequant tables are
    # such constants.
    return comp.as_hlo_text(print_large_constants=True)


def write_weights_bin(path: str, cfg: M.ModelConfig, weights) -> None:
    """TKVW format: magic, version u32, count u32, then per tensor:
    name_len u32, name bytes, ndim u32, dims u32[], data f32 LE."""
    with open(path, "wb") as f:
        f.write(b"TKVW")
        f.write(struct.pack("<II", 1, len(weights)))
        for (name, shape), w in zip(cfg.weight_specs(), weights):
            arr = np.asarray(w, dtype=np.float32)
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def write_quant_golden(path: str, seed: int = 7, n: int = 8, d: int = 64) -> None:
    """TKVG format: magic, version, ntags, n, d, g u32; then per tag
    (0,1,2): x f32[n,d], codes u8[n,d], scales f32[n,d/g], deq f32[n,d]."""
    rng = np.random.default_rng(seed)
    g = F.GROUP_SIZE
    with open(path, "wb") as f:
        f.write(b"TKVG")
        f.write(struct.pack("<IIIII", 1, 3, n, d, g))
        for tag in (F.TAG_TERNARY, F.TAG_NVFP4, F.TAG_FP8):
            x = (rng.normal(size=(n, d)) * rng.uniform(0.2, 3.0)).astype(np.float32)
            codes, scales = R.quant_groups_ref(x, tag)
            deq = R.dequant_groups_ref(codes, scales, tag)
            f.write(x.astype("<f4").tobytes())
            f.write(codes.astype(np.uint8).tobytes())
            f.write(scales.astype("<f4").tobytes())
            f.write(deq.astype("<f4").tobytes())


def write_attn_golden(path: str, cfg: M.ModelConfig, seed: int = 11) -> None:
    """TKVA format: one fused-attention case at C=GOLDEN_ATTN_C.

    Header: magic, version, H, Hkv, D, G, C, BUF u32.  Arrays in order:
    q f32[H,D], k_codes u8[C,Hkv,D], k_scales f32[C,Hkv,G], v_codes,
    v_scales, tags u8[C], mask f32[C], buf_k f32[BUF,Hkv,D], buf_v,
    buf_mask f32[BUF], out f32[H,D], probs f32[H,C+BUF].
    """
    rng = np.random.default_rng(seed)
    H, Hkv, D, G, BUF, C = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                            cfg.groups, cfg.buf_slots, GOLDEN_ATTN_C)
    q = rng.normal(size=(H, D)).astype(np.float32)
    kf = rng.normal(size=(C, Hkv, D)).astype(np.float32)
    vf = rng.normal(size=(C, Hkv, D)).astype(np.float32)
    tags = rng.integers(0, 3, size=(C,)).astype(np.uint8)
    mask = (rng.random(C) < 0.75).astype(np.float32)
    kc = np.zeros((C, Hkv, D), np.uint8)
    ks = np.zeros((C, Hkv, G), np.float32)
    vc = np.zeros_like(kc)
    vs = np.zeros_like(ks)
    for i in range(C):
        kc[i], ks[i] = R.quant_groups_ref(kf[i], int(tags[i]))
        vc[i], vs[i] = R.quant_groups_ref(vf[i], int(tags[i]))
    bk = rng.normal(size=(BUF, Hkv, D)).astype(np.float32)
    bv = rng.normal(size=(BUF, Hkv, D)).astype(np.float32)
    bm = (rng.random(BUF) < 0.5).astype(np.float32)
    out, probs = R.fused_paged_attention_ref(q, kc, ks, vc, vs, tags, mask, bk, bv, bm)
    with open(path, "wb") as f:
        f.write(b"TKVA")
        f.write(struct.pack("<IIIIIII", 1, H, Hkv, D, G, C, BUF))
        for arr in (q, kc, ks, vc, vs, tags, mask, bk, bv, bm, out, probs):
            a = np.asarray(arr)
            f.write(a.astype("<f4").tobytes() if a.dtype != np.uint8 else a.tobytes())


def weight_structs(cfg: M.ModelConfig):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.weight_specs()]


def lower_all(outdir: str, cfg: M.ModelConfig, verbose: bool = True):
    os.makedirs(outdir, exist_ok=True)
    ws = weight_structs(cfg)
    S = jax.ShapeDtypeStruct
    artifacts = {}

    def emit(name, fn, *args):
        if verbose:
            print(f"  lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = f"{name}.hlo.txt"
        if verbose:
            print(f"    -> {len(text)} chars", flush=True)

    # Prefill
    emit(f"prefill_p{cfg.prefill_len}",
         functools.partial(M.prefill, cfg),
         ws, S((cfg.prefill_len,), jnp.int32))

    # Quantized decode variants
    for c in QUANT_CAPS:
        sh = M.decode_quant_shapes(cfg, c)
        emit(f"decode_quant_c{c}",
             functools.partial(M.decode_step_quant, cfg),
             ws, sh["token"], sh["pos"], sh["buf_idx"],
             sh["k_codes"], sh["k_scales"], sh["v_codes"], sh["v_scales"],
             sh["tags"], sh["mask"], sh["buf_k"], sh["buf_v"], sh["buf_mask"])

    # FP32 decode variants
    for c in FP32_CAPS:
        sh = M.decode_fp32_shapes(cfg, c)
        emit(f"decode_fp32_c{c}",
             functools.partial(M.decode_step_fp32, cfg),
             ws, sh["token"], sh["pos"], sh["buf_idx"],
             sh["k_cache"], sh["v_cache"], sh["mask"],
             sh["buf_k"], sh["buf_v"], sh["buf_mask"])

    # Fused multi-request decode: one execute per fused step.  Every
    # (capacity, batch-width) pair of both families, so the engine can
    # pick the smallest compiled width covering any runnable batch.
    for c in QUANT_CAPS:
        for b in BATCH_WIDTHS:
            sh = M.decode_quant_batch_shapes(cfg, c, b)
            emit(f"decode_quant_c{c}_b{b}",
                 functools.partial(M.decode_step_quant_batch, cfg),
                 ws, sh["token"], sh["pos"], sh["buf_idx"],
                 sh["member"], sh["block_tables"],
                 sh["k_codes"], sh["k_scales"], sh["v_codes"], sh["v_scales"],
                 sh["tags"], sh["mask"], sh["buf_k"], sh["buf_v"], sh["buf_mask"])
    for c in FP32_CAPS:
        for b in BATCH_WIDTHS:
            sh = M.decode_fp32_batch_shapes(cfg, c, b)
            emit(f"decode_fp32_c{c}_b{b}",
                 functools.partial(M.decode_step_fp32_batch, cfg),
                 ws, sh["token"], sh["pos"], sh["buf_idx"],
                 sh["member"], sh["block_tables"],
                 sh["k_cache"], sh["v_cache"], sh["mask"],
                 sh["buf_k"], sh["buf_v"], sh["buf_mask"])

    # Chunked prefill: one execute per prompt chunk, full-width K/V view
    # so chunked composition is bit-identical to the whole-prompt module.
    for n in PREFILL_CHUNK_LENS:
        sh = M.prefill_chunk_shapes(cfg, n)
        emit(f"prefill_chunk_p{cfg.prefill_len}_n{n}",
             functools.partial(M.prefill_chunk, cfg),
             ws, sh["tokens"], sh["start"], sh["past_k"], sh["past_v"])

    # Standalone fused attention microbench
    from compile.kernels import paged_attn as PA
    H, Hkv, D, G, B = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.groups,
                       cfg.buf_slots)
    emit(f"attn_micro_c{MICRO_C}",
         lambda *a: PA.fused_paged_attention(*a),
         S((H, D), jnp.float32),
         S((MICRO_C, Hkv, D), jnp.uint8), S((MICRO_C, Hkv, G), jnp.float32),
         S((MICRO_C, Hkv, D), jnp.uint8), S((MICRO_C, Hkv, G), jnp.float32),
         S((MICRO_C,), jnp.uint8), S((MICRO_C,), jnp.float32),
         S((B, Hkv, D), jnp.float32), S((B, Hkv, D), jnp.float32),
         S((B,), jnp.float32))

    return artifacts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    cfg = M.ModelConfig()

    print("ThinKV AOT export", flush=True)
    weights = M.init_weights(cfg, seed=args.seed)
    write_weights_bin(os.path.join(outdir, "weights.bin"), cfg, weights)
    write_quant_golden(os.path.join(outdir, "quant_golden.bin"))
    write_attn_golden(os.path.join(outdir, "attn_golden.bin"), cfg)

    artifacts = lower_all(outdir, cfg)

    config = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
            "d_ffn": cfg.d_ffn, "rope_base": cfg.rope_base,
            "buf_slots": cfg.buf_slots, "prefill_len": cfg.prefill_len,
            "obs_window": cfg.obs_window, "group_size": F.GROUP_SIZE,
        },
        "capacities": {"quant": QUANT_CAPS, "fp32": FP32_CAPS},
        "batch_widths": BATCH_WIDTHS,
        "prefill_chunk_lens": PREFILL_CHUNK_LENS,
        "micro_c": MICRO_C,
        "golden_attn_c": GOLDEN_ATTN_C,
        "artifacts": artifacts,
        "weights": [{"name": n, "shape": list(s)} for n, s in cfg.weight_specs()],
        "seed": args.seed,
    }
    with open(os.path.join(outdir, "model_config.json"), "w") as f:
        json.dump(config, f, indent=1)
    print(f"wrote {len(artifacts)} HLO artifacts + weights/golden/config to {outdir}")


if __name__ == "__main__":
    main()
