"""L2: the JAX reasoning-model forward pass (build-time only).

A decoder-only transformer (RMSNorm, RoPE, GQA, GELU MLP) whose decode step
attends over the ThinKV **quantized paged cache** through the L1 fused
Pallas kernel.  `aot.py` lowers these functions once to HLO text; the Rust
coordinator executes them via PJRT and owns every byte of cache state —
Python never runs on the request path.

Cache layout seen by the decode step (one tensor set per layer):
  k_codes/v_codes u8   [L, C, Hkv, Dh]   quantized slots (uniform u8 lanes)
  k_scales/v_scales f32[L, C, Hkv, Dh/g] E4M3-snapped group scales
  tags u8             [L, C]             slot precision (0=ternary,1=nvfp4,2=fp8)
  mask f32            [L, C]             slot validity (CT eviction mask ∘ fill)
  buf_k/buf_v f32     [L, BUF, Hkv, Dh]  full-precision ring buffer (B_buf, §4.2)
  buf_mask f32        [L, BUF]
Slot order is arbitrary (attention is permutation invariant, Theorem 1) —
that is the property Continuous Thinking exploits for in-place slot reuse.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile import formats as F
from compile.kernels import paged_attn as PA


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ffn: int = 256
    rope_base: float = 10000.0
    buf_slots: int = 16          # B_buf — must equal the quant group size g
    prefill_len: int = 64
    obs_window: int = 8          # SnapKV observation window
    eps: float = 1e-5

    @property
    def groups(self) -> int:
        return self.d_head // F.GROUP_SIZE

    def weight_specs(self) -> List[tuple]:
        """(name, shape) in the exact flattened parameter order of the HLO."""
        specs = [("embed", (self.vocab, self.d_model))]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.ln1", (self.d_model,)),
                (f"l{l}.wq", (self.d_model, self.n_heads * self.d_head)),
                (f"l{l}.wk", (self.d_model, self.n_kv_heads * self.d_head)),
                (f"l{l}.wv", (self.d_model, self.n_kv_heads * self.d_head)),
                (f"l{l}.wo", (self.n_heads * self.d_head, self.d_model)),
                (f"l{l}.ln2", (self.d_model,)),
                (f"l{l}.w1", (self.d_model, self.d_ffn)),
                (f"l{l}.w2", (self.d_ffn, self.d_model)),
            ]
        specs += [("lnf", (self.d_model,)), ("lm_head", (self.d_model, self.vocab))]
        return specs


def init_weights(cfg: ModelConfig, seed: int = 1234) -> List[jnp.ndarray]:
    """Seeded random weights (scaled for stable logits); order = weight_specs."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = []
    for name, shape in cfg.weight_specs():
        if name.endswith(("ln1", "ln2")) or name == "lnf":
            w = np.ones(shape, np.float32)
        else:
            fan_in = shape[0]
            w = rng.normal(0.0, 1.0 / np.sqrt(fan_in), size=shape).astype(np.float32)
        out.append(jnp.asarray(w))
    return out


def rmsnorm(x, w, eps):
    return x / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope(x, pos, base):
    """x: (..., D); pos: scalar or (...,)-broadcastable int32 position(s)."""
    d = x.shape[-1]
    half = d // 2
    inv = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.asarray(pos, jnp.float32)[..., None] * inv  # (..., half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unpack_weights(cfg: ModelConfig, weights):
    it = iter(weights)
    embed = next(it)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(tuple(next(it) for _ in range(8)))
    lnf = next(it)
    lm_head = next(it)
    return embed, layers, lnf, lm_head


def _mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


def _decode_body_quant(cfg: ModelConfig, parts, tok, p, bidx,
                       k_codes, k_scales, v_codes, v_scales, tags, mask,
                       buf_k, buf_v, buf_mask):
    """One request's decode step over a request-local quantized cache view.

    Shared verbatim by the single-request artifact and every lane of the
    batched artifacts, so a fused batch is numerically the same program
    per member as B single executes (stream invariance).
    """
    embed, layers, lnf, lm_head = parts
    x = embed[tok]
    new_ks, new_vs, prob_rows = [], [], []
    for l, (ln1, wq, wk, wv, wo, ln2, w1, w2) in enumerate(layers):
        h = rmsnorm(x, ln1, cfg.eps)
        q = rope((h @ wq).reshape(cfg.n_heads, cfg.d_head), p, cfg.rope_base)
        k = rope((h @ wk).reshape(cfg.n_kv_heads, cfg.d_head), p, cfg.rope_base)
        v = (h @ wv).reshape(cfg.n_kv_heads, cfg.d_head)
        # Current token enters the fp ring buffer at buf_idx.
        bk = jax.lax.dynamic_update_slice(buf_k[l], k[None], (bidx, 0, 0))
        bv = jax.lax.dynamic_update_slice(buf_v[l], v[None], (bidx, 0, 0))
        bm = buf_mask[l].at[bidx].set(1.0)
        attn, probs = PA.fused_paged_attention(
            q, k_codes[l], k_scales[l], v_codes[l], v_scales[l],
            tags[l], mask[l], bk, bv, bm)
        x = x + attn.reshape(-1) @ wo
        x = x + _mlp(rmsnorm(x, ln2, cfg.eps), w1, w2)
        new_ks.append(k)
        new_vs.append(v)
        prob_rows.append(probs)
    logits = rmsnorm(x, lnf, cfg.eps) @ lm_head
    return logits, jnp.stack(new_ks), jnp.stack(new_vs), jnp.stack(prob_rows)


def _decode_body_fp32(cfg: ModelConfig, parts, tok, p, bidx,
                      k_cache, v_cache, mask, buf_k, buf_v, buf_mask):
    """One request's decode step over a request-local f32 cache view."""
    embed, layers, lnf, lm_head = parts
    x = embed[tok]
    new_ks, new_vs, prob_rows = [], [], []
    for l, (ln1, wq, wk, wv, wo, ln2, w1, w2) in enumerate(layers):
        h = rmsnorm(x, ln1, cfg.eps)
        q = rope((h @ wq).reshape(cfg.n_heads, cfg.d_head), p, cfg.rope_base)
        k = rope((h @ wk).reshape(cfg.n_kv_heads, cfg.d_head), p, cfg.rope_base)
        v = (h @ wv).reshape(cfg.n_kv_heads, cfg.d_head)
        bk = jax.lax.dynamic_update_slice(buf_k[l], k[None], (bidx, 0, 0))
        bv = jax.lax.dynamic_update_slice(buf_v[l], v[None], (bidx, 0, 0))
        bm = buf_mask[l].at[bidx].set(1.0)
        attn, probs = PA.paged_attention_fp32(
            q, k_cache[l], v_cache[l], mask[l], bk, bv, bm)
        x = x + attn.reshape(-1) @ wo
        x = x + _mlp(rmsnorm(x, ln2, cfg.eps), w1, w2)
        new_ks.append(k)
        new_vs.append(v)
        prob_rows.append(probs)
    logits = rmsnorm(x, lnf, cfg.eps) @ lm_head
    return logits, jnp.stack(new_ks), jnp.stack(new_vs), jnp.stack(prob_rows)


def decode_step_quant(cfg: ModelConfig, weights, token, pos, buf_idx,
                      k_codes, k_scales, v_codes, v_scales, tags, mask,
                      buf_k, buf_v, buf_mask):
    """One decode step over the quantized paged cache (the ThinKV hot path).

    Returns (logits (V,), new_k (L,Hkv,Dh) post-RoPE, new_v (L,Hkv,Dh),
    probs (L,H,C+BUF)).  The caller (Rust) quantizes new_k/new_v by the
    active thought type and writes them into slots chosen by CT.
    """
    parts = _unpack_weights(cfg, weights)
    return _decode_body_quant(cfg, parts, token[0], pos[0], buf_idx[0],
                              k_codes, k_scales, v_codes, v_scales, tags, mask,
                              buf_k, buf_v, buf_mask)


def decode_step_fp32(cfg: ModelConfig, weights, token, pos, buf_idx,
                     k_cache, v_cache, mask, buf_k, buf_v, buf_mask):
    """FullKV / eviction-only baselines: f32 paged cache, same structure."""
    parts = _unpack_weights(cfg, weights)
    return _decode_body_fp32(cfg, parts, token[0], pos[0], buf_idx[0],
                             k_cache, v_cache, mask, buf_k, buf_v, buf_mask)


def decode_step_quant_batch(cfg: ModelConfig, weights, token, pos, buf_idx,
                            member, block_tables,
                            k_codes, k_scales, v_codes, v_scales, tags, mask,
                            buf_k, buf_v, buf_mask):
    """Fused multi-request decode: B stacked requests, ONE module execute.

    The paper's extended-PagedAttention shape (§kernel): per-request block
    tables gather each lane's cache view out of one shared physical arena,
    so heterogeneous sessions — including sessions aliasing one resident
    copy of a shared system-prompt prefix — advance in a single launch.

      token/pos/buf_idx (B,) i32     per-lane decode scalars
      member (B,) f32                1 = live lane, 0 = ragged-batch padding
      block_tables (B, L, C) i32     arena row index per lane/layer/slot
      k_codes (L, A, Hkv, Dh) u8     shared payload arena, A = B*C +
      k_scales (L, A, Hkv, G) f32      prefill_len (one extra prefix
                                       segment); v_* alike
      tags (B, L, C) u8              per-lane slot metadata: tags and the
      mask (B, L, C) f32               CT eviction mask diverge per
                                       session even over aliased payload
      buf_k/buf_v (B, L, BUF, Hkv, Dh) f32, buf_mask (B, L, BUF) f32

    Returns the stacked single-request outputs — logits (B,V),
    new_k/new_v (B,L,Hkv,Dh), probs (B,L,H,C+BUF) — with padded lanes
    zeroed by `member`.  Each live lane runs `_decode_body_quant`
    verbatim on its gathered view, so a fused step is numerically
    identical to B single-request executes (stream invariance).
    """
    parts = _unpack_weights(cfg, weights)
    bw = token.shape[0]
    outs = []
    for b in range(bw):
        bt = block_tables[b]  # (L, C)
        o = _decode_body_quant(
            cfg, parts, token[b], pos[b], buf_idx[b],
            PA.gather_block_rows(k_codes, bt), PA.gather_block_rows(k_scales, bt),
            PA.gather_block_rows(v_codes, bt), PA.gather_block_rows(v_scales, bt),
            tags[b], mask[b],
            buf_k[b], buf_v[b], buf_mask[b])
        outs.append(tuple(member[b] * t for t in o))
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))


def decode_step_fp32_batch(cfg: ModelConfig, weights, token, pos, buf_idx,
                           member, block_tables,
                           k_cache, v_cache, mask, buf_k, buf_v, buf_mask):
    """Fused multi-request decode over the f32 arena (FullKV / eviction
    baselines) — same block-table gather contract as
    `decode_step_quant_batch`."""
    parts = _unpack_weights(cfg, weights)
    bw = token.shape[0]
    outs = []
    for b in range(bw):
        bt = block_tables[b]
        o = _decode_body_fp32(
            cfg, parts, token[b], pos[b], buf_idx[b],
            PA.gather_block_rows(k_cache, bt), PA.gather_block_rows(v_cache, bt),
            mask[b],
            buf_k[b], buf_v[b], buf_mask[b])
        outs.append(tuple(member[b] * t for t in o))
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))


def prefill_chunk(cfg: ModelConfig, weights, tokens, start, past_k, past_v):
    """One prompt chunk (N tokens) attended against the full prefill view.

    Chunked prefill as ONE artifact execute per chunk: `tokens` is the
    prompt slice for positions `start .. start+N`, and `past_k`/`past_v`
    are the exact post-RoPE K/V rows produced by earlier chunks (rows at
    or past `start` are ignored — this chunk's own K/V overwrite them at
    their true positions).  Scores keep the full `(H, N, P)` width of the
    whole-prompt prefill with the same causal mask per global row, so
    every per-row reduction has the shape and operand values of the
    corresponding row in [`prefill`] — chunked composition is
    structurally bit-identical to one whole-prompt execute.

    Returns (logits (V,) from the chunk's last row — meaningful only on
    the final chunk, k (L,N,Hkv,Dh) post-RoPE, v (L,N,Hkv,Dh),
    obs (L,N) zeros — the SnapKV statistic needs the last `obs_window`
    global queries, so obs-consuming modes take the whole-prompt path).
    """
    embed, layers, lnf, lm_head = _unpack_weights(cfg, weights)
    P = cfg.prefill_len
    N = tokens.shape[0]
    s0 = start[0]
    x = embed[tokens]                                    # (N, Dm)
    positions = s0 + jnp.arange(N)
    cols = jnp.arange(P)
    causal = (cols[None, :] <= positions[:, None]).astype(jnp.float32)  # (N, P)
    rep = cfg.n_heads // cfg.n_kv_heads
    ks, vs = [], []
    for l, (ln1, wq, wk, wv, wo, ln2, w1, w2) in enumerate(layers):
        h = rmsnorm(x, ln1, cfg.eps)
        q = rope((h @ wq).reshape(N, cfg.n_heads, cfg.d_head).transpose(1, 0, 2),
                 positions[None, :], cfg.rope_base)      # (H, N, Dh)
        k = rope((h @ wk).reshape(N, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2),
                 positions[None, :], cfg.rope_base)      # (Hkv, N, Dh)
        v = (h @ wv).reshape(N, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        # Full-width K/V: exact past rows, this chunk spliced at its true
        # positions, future rows masked off by `causal` anyway.
        kf = jax.lax.dynamic_update_slice(
            past_k[l].transpose(1, 0, 2), k, (0, s0, 0))  # (Hkv, P, Dh)
        vf = jax.lax.dynamic_update_slice(
            past_v[l].transpose(1, 0, 2), v, (0, s0, 0))
        kx = jnp.repeat(kf, rep, axis=0)                 # (H, P, Dh)
        vx = jnp.repeat(vf, rep, axis=0)
        s = jnp.einsum("hqd,hkd->hqk", q, kx) / jnp.sqrt(jnp.float32(cfg.d_head))
        s = jnp.where(causal[None] > 0, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)                   # (H, N, P)
        attn = jnp.einsum("hqk,hkd->hqd", p, vx)
        attn = attn.transpose(1, 0, 2).reshape(N, -1)
        x = x + attn @ wo
        x = x + _mlp(rmsnorm(x, ln2, cfg.eps), w1, w2)
        ks.append(k.transpose(1, 0, 2))                  # (N, Hkv, Dh)
        vs.append(v.transpose(1, 0, 2))
    logits = rmsnorm(x[-1], lnf, cfg.eps) @ lm_head
    obs = jnp.zeros((cfg.n_layers, N), jnp.float32)
    return logits, jnp.stack(ks), jnp.stack(vs), obs


def prefill(cfg: ModelConfig, weights, tokens):
    """Prompt prefill (P tokens, full causal attention, plain fused HLO).

    Returns (logits (V,) for the last position, k (L,P,Hkv,Dh) post-RoPE,
    v (L,P,Hkv,Dh), obs (L,P) = mean attention received by each position
    from the last `obs_window` queries — the SnapKV observation statistic).
    """
    embed, layers, lnf, lm_head = _unpack_weights(cfg, weights)
    P = tokens.shape[0]
    x = embed[tokens]  # (P, Dm)
    positions = jnp.arange(P)
    causal = jnp.tril(jnp.ones((P, P), jnp.float32))
    rep = cfg.n_heads // cfg.n_kv_heads
    ks, vs, obs_rows = [], [], []
    for (ln1, wq, wk, wv, wo, ln2, w1, w2) in layers:
        h = rmsnorm(x, ln1, cfg.eps)
        q = rope((h @ wq).reshape(P, cfg.n_heads, cfg.d_head).transpose(1, 0, 2),
                 positions[None, :], cfg.rope_base)     # (H, P, Dh)
        k = rope((h @ wk).reshape(P, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2),
                 positions[None, :], cfg.rope_base)     # (Hkv, P, Dh)
        v = (h @ wv).reshape(P, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
        kx = jnp.repeat(k, rep, axis=0)                 # (H, P, Dh)
        vx = jnp.repeat(v, rep, axis=0)
        s = jnp.einsum("hqd,hkd->hqk", q, kx) / jnp.sqrt(jnp.float32(cfg.d_head))
        s = jnp.where(causal[None] > 0, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)                  # (H, Q, K)
        attn = jnp.einsum("hqk,hkd->hqd", p, vx)
        attn = attn.transpose(1, 0, 2).reshape(P, -1)
        x = x + attn @ wo
        x = x + _mlp(rmsnorm(x, ln2, cfg.eps), w1, w2)
        ks.append(k.transpose(1, 0, 2))                 # (P, Hkv, Dh)
        vs.append(v.transpose(1, 0, 2))
        obs_rows.append(jnp.mean(p[:, P - cfg.obs_window:, :], axis=(0, 1)))  # (P,)
    logits = rmsnorm(x[-1], lnf, cfg.eps) @ lm_head
    return logits, jnp.stack(ks), jnp.stack(vs), jnp.stack(obs_rows)


# ---------------------------------------------------------------------------
# Shape helpers for lowering (aot.py) and tests
# ---------------------------------------------------------------------------

def decode_quant_shapes(cfg: ModelConfig, capacity: int):
    L, C, Hkv, Dh, G, B = (cfg.n_layers, capacity, cfg.n_kv_heads,
                           cfg.d_head, cfg.groups, cfg.buf_slots)
    f32, u8, i32 = jnp.float32, jnp.uint8, jnp.int32
    S = jax.ShapeDtypeStruct
    return dict(
        token=S((1,), i32), pos=S((1,), i32), buf_idx=S((1,), i32),
        k_codes=S((L, C, Hkv, Dh), u8), k_scales=S((L, C, Hkv, G), f32),
        v_codes=S((L, C, Hkv, Dh), u8), v_scales=S((L, C, Hkv, G), f32),
        tags=S((L, C), u8), mask=S((L, C), f32),
        buf_k=S((L, B, Hkv, Dh), f32), buf_v=S((L, B, Hkv, Dh), f32),
        buf_mask=S((L, B), f32),
    )


def decode_quant_batch_shapes(cfg: ModelConfig, capacity: int, bw: int):
    """Batched-artifact input shapes: B stacked requests over one arena.

    The arena carries `bw` request-private segments of `capacity` slots
    plus one `prefill_len` segment for a shared prompt prefix aliased by
    any subset of the lanes (rows are only reachable through block
    tables, so unshared batches simply never index the extra segment).
    """
    L, C, Hkv, Dh, G, B = (cfg.n_layers, capacity, cfg.n_kv_heads,
                           cfg.d_head, cfg.groups, cfg.buf_slots)
    A = bw * capacity + cfg.prefill_len
    f32, u8, i32 = jnp.float32, jnp.uint8, jnp.int32
    S = jax.ShapeDtypeStruct
    return dict(
        token=S((bw,), i32), pos=S((bw,), i32), buf_idx=S((bw,), i32),
        member=S((bw,), f32), block_tables=S((bw, L, C), i32),
        k_codes=S((L, A, Hkv, Dh), u8), k_scales=S((L, A, Hkv, G), f32),
        v_codes=S((L, A, Hkv, Dh), u8), v_scales=S((L, A, Hkv, G), f32),
        tags=S((bw, L, C), u8), mask=S((bw, L, C), f32),
        buf_k=S((bw, L, B, Hkv, Dh), f32), buf_v=S((bw, L, B, Hkv, Dh), f32),
        buf_mask=S((bw, L, B), f32),
    )


def decode_fp32_batch_shapes(cfg: ModelConfig, capacity: int, bw: int):
    L, C, Hkv, Dh, B = cfg.n_layers, capacity, cfg.n_kv_heads, cfg.d_head, cfg.buf_slots
    A = bw * capacity + cfg.prefill_len
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    return dict(
        token=S((bw,), i32), pos=S((bw,), i32), buf_idx=S((bw,), i32),
        member=S((bw,), f32), block_tables=S((bw, L, C), i32),
        k_cache=S((L, A, Hkv, Dh), f32), v_cache=S((L, A, Hkv, Dh), f32),
        mask=S((bw, L, C), f32),
        buf_k=S((bw, L, B, Hkv, Dh), f32), buf_v=S((bw, L, B, Hkv, Dh), f32),
        buf_mask=S((bw, L, B), f32),
    )


def prefill_chunk_shapes(cfg: ModelConfig, n: int):
    L, P, Hkv, Dh = cfg.n_layers, cfg.prefill_len, cfg.n_kv_heads, cfg.d_head
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    return dict(
        tokens=S((n,), i32), start=S((1,), i32),
        past_k=S((L, P, Hkv, Dh), f32), past_v=S((L, P, Hkv, Dh), f32),
    )


def decode_fp32_shapes(cfg: ModelConfig, capacity: int):
    L, C, Hkv, Dh, B = cfg.n_layers, capacity, cfg.n_kv_heads, cfg.d_head, cfg.buf_slots
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    return dict(
        token=S((1,), i32), pos=S((1,), i32), buf_idx=S((1,), i32),
        k_cache=S((L, C, Hkv, Dh), f32), v_cache=S((L, C, Hkv, Dh), f32),
        mask=S((L, C), f32),
        buf_k=S((L, B, Hkv, Dh), f32), buf_v=S((L, B, Hkv, Dh), f32),
        buf_mask=S((L, B), f32),
    )
