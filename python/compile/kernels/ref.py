"""Pure-jnp/numpy oracles for every L1 kernel.

These are the correctness ground truth: pytest asserts that the Pallas
kernels (interpret=True) match these references, and aot.py emits golden
vectors from *these* functions so the Rust quantizer can be cross-checked
against the same source of truth.
"""

from __future__ import annotations

import numpy as np

from compile import formats as F


# --------------------------------------------------------------------------
# Group quantization references (numpy; exact intended semantics)
# --------------------------------------------------------------------------

def quant_groups_ref(x: np.ndarray, tag: int):
    """Quantize `x` (..., D) with format `tag`, groups of g=16 on the last dim.

    Returns (codes u8 (..., D), scales f32 (..., D/g)).
    """
    g = F.GROUP_SIZE
    x = np.asarray(x, dtype=np.float32)
    assert x.shape[-1] % g == 0
    gs = x.reshape(*x.shape[:-1], x.shape[-1] // g, g)

    if tag == F.TAG_FP8:
        # Per-entry scale = max|x| over the whole vector / FP8_MAX, snapped to
        # the E4M3 grid and replicated across groups (uniform interface).
        amax = np.max(np.abs(x), axis=-1, keepdims=True)
        scale = F.e4m3_snap(amax / F.FP8_MAX)
        scale = np.where(scale <= 0, np.float32(1.0), scale)
        codes = F.e4m3_encode(x / scale)
        scales = np.broadcast_to(scale, (*x.shape[:-1], x.shape[-1] // g)).copy()
        return codes.astype(np.uint8), scales.astype(np.float32)

    if tag == F.TAG_NVFP4:
        amax = np.max(np.abs(gs), axis=-1, keepdims=True)
        scale = F.e4m3_snap(amax / F.NVFP4_MAX)
        scale = np.where(scale <= 0, np.float32(1.0), scale)
        t = gs / scale
        # nearest of the 8 magnitudes, with sign
        mag = np.abs(t)[..., None]  # (..., g, 1)
        idx = np.argmin(np.abs(mag - F.NVFP4_MAG), axis=-1)
        sign = (t < 0).astype(np.uint8)
        codes = (sign * 8 + idx.astype(np.uint8)).astype(np.uint8)
        return (
            codes.reshape(*x.shape),
            scale[..., 0].astype(np.float32),
        )

    if tag == F.TAG_TERNARY:
        amean = np.mean(np.abs(gs), axis=-1, keepdims=True)
        scale = F.e4m3_snap(amean)
        scale = np.where(scale <= 0, np.float32(1.0), scale)
        t = gs / scale
        # codes: 0 -> 0, 1 -> +1, 2 -> -1
        codes = np.where(t > 0.5, np.uint8(1), np.where(t < -0.5, np.uint8(2), np.uint8(0)))
        return codes.reshape(*x.shape).astype(np.uint8), scale[..., 0].astype(np.float32)

    raise ValueError(f"unknown tag {tag}")


def dequant_groups_ref(codes: np.ndarray, scales: np.ndarray, tag: int) -> np.ndarray:
    """Inverse of quant_groups_ref (codes (...,D), scales (...,D/g)) -> f32."""
    g = F.GROUP_SIZE
    codes = np.asarray(codes)
    sc = np.repeat(np.asarray(scales, dtype=np.float32), g, axis=-1)
    if tag == F.TAG_FP8:
        return F.E4M3_TABLE[codes] * sc
    if tag == F.TAG_NVFP4:
        mag = F.NVFP4_MAG[codes & 7]
        sign = np.where((codes & 8) != 0, np.float32(-1.0), np.float32(1.0))
        return sign * mag * sc
    if tag == F.TAG_TERNARY:
        val = np.where(codes == 1, np.float32(1.0), np.where(codes == 2, np.float32(-1.0), np.float32(0.0)))
        return val * sc
    raise ValueError(f"unknown tag {tag}")


def dequant_any_ref(codes: np.ndarray, scales: np.ndarray, tags: np.ndarray) -> np.ndarray:
    """Per-slot tagged dequantization.

    codes: (C, Hkv, D) u8, scales: (C, Hkv, D/g) f32, tags: (C,) u8.
    """
    codes = np.asarray(codes)
    out = np.zeros(codes.shape, dtype=np.float32)
    tags = np.asarray(tags)
    for t in (F.TAG_TERNARY, F.TAG_NVFP4, F.TAG_FP8):
        sel = tags == t
        if sel.any():
            out[sel] = dequant_groups_ref(codes[sel], np.asarray(scales)[sel], t)
    return out


# --------------------------------------------------------------------------
# Attention references
# --------------------------------------------------------------------------

def paged_attention_fp32_ref(q, k, v, mask):
    """Masked decode attention, f32 cache.

    q: (H, D); k, v: (C, Hkv, D); mask: (C,) in {0,1}.
    Returns (out (H, D), probs (H, C)).
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    v = np.asarray(v, np.float32)
    mask = np.asarray(mask, np.float32)
    H, D = q.shape
    C, Hkv, _ = k.shape
    rep = H // Hkv
    out = np.zeros((H, D), np.float32)
    probs = np.zeros((H, C), np.float32)
    for h in range(H):
        kh = k[:, h // rep, :]
        vh = v[:, h // rep, :]
        s = kh @ q[h] / np.sqrt(D)
        s = np.where(mask > 0, s, -np.inf)
        m = np.max(s)
        if not np.isfinite(m):
            continue  # fully masked
        e = np.where(mask > 0, np.exp(s - m), 0.0)
        z = e.sum()
        p = e / z
        probs[h] = p
        out[h] = p @ vh
    return out, probs


def fused_paged_attention_ref(q, k_codes, k_scales, v_codes, v_scales, tags, mask,
                              buf_k, buf_v, buf_mask):
    """Reference for the fused dequant + paged attention kernel.

    Quantized region (C slots) + full-precision ring buffer (BUF slots).
    Returns (out (H, D), probs (H, C+BUF)).
    """
    k_deq = dequant_any_ref(k_codes, k_scales, tags)
    v_deq = dequant_any_ref(v_codes, v_scales, tags)
    k_all = np.concatenate([k_deq, np.asarray(buf_k, np.float32)], axis=0)
    v_all = np.concatenate([v_deq, np.asarray(buf_v, np.float32)], axis=0)
    m_all = np.concatenate([np.asarray(mask, np.float32), np.asarray(buf_mask, np.float32)])
    return paged_attention_fp32_ref(q, k_all, v_all, m_all)


# --------------------------------------------------------------------------
# Model-side references
# --------------------------------------------------------------------------

def rmsnorm_ref(x, w, eps=1e-5):
    x = np.asarray(x, np.float32)
    return x / np.sqrt(np.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope_ref(x, pos, *, base=10000.0):
    """x: (..., D) with D even; pos: scalar int."""
    x = np.asarray(x, np.float32)
    D = x.shape[-1]
    half = D // 2
    inv = base ** (-np.arange(half, dtype=np.float32) / half)
    ang = pos * inv
    cos, sin = np.cos(ang), np.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return np.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
