"""L1 Pallas group-quantization kernels + jnp format helpers.

Implements the paper's TBQ data formats (§4.2, §D.3) as Pallas kernels:
FP8 E4M3 / NVFP4 (E2M1, g=16) / Ternary (g=16), each with E4M3-snapped
scales.  `interpret=True` everywhere: real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot run (see DESIGN §Hardware-Adaptation).

Pallas kernels cannot capture constant arrays, so the format lookup tables
are threaded through as explicit kernel inputs (`Tables`).  The jnp helpers
are shared with the fused attention kernel so the decode path and the quant
path use identical tables.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import formats as F


class Tables(NamedTuple):
    """Format lookup tables, passed explicitly into Pallas kernels."""

    e4m3_table: jax.Array  # (256,) f32 decode table
    pos_vals: jax.Array    # (121,) f32 sorted non-negative E4M3 magnitudes
    pos_codes: jax.Array   # (121,) u8 codes for pos_vals
    nvfp4_mag: jax.Array   # (8,) f32 E2M1 magnitudes


def tables_jnp() -> Tables:
    return Tables(
        jnp.asarray(F.E4M3_TABLE),
        jnp.asarray(F.E4M3_POS_VALUES),
        jnp.asarray(F.E4M3_POS_CODES),
        jnp.asarray(F.NVFP4_MAG),
    )


# --------------------------------------------------------------------------
# jnp format primitives (shared by quant + attention kernels)
# --------------------------------------------------------------------------

def e4m3_encode_jnp(x, t: Tables):
    """Nearest-value FP8 E4M3 encode; ties toward the smaller magnitude."""
    mag = jnp.clip(jnp.abs(x), 0.0, F.FP8_MAX)
    idx = jnp.argmin(jnp.abs(mag[..., None] - t.pos_vals), axis=-1)
    code = t.pos_codes[idx]
    return jnp.where(jnp.signbit(x), code | jnp.uint8(0x80), code)


def e4m3_decode_jnp(codes, t: Tables):
    return t.e4m3_table[codes.astype(jnp.int32)]


def e4m3_snap_jnp(x, t: Tables):
    return e4m3_decode_jnp(e4m3_encode_jnp(x, t), t)


def nvfp4_encode_jnp(x, t: Tables):
    """Encode already-scaled values to NVFP4 codes (sign*8 + mag idx)."""
    idx = jnp.argmin(jnp.abs(jnp.abs(x)[..., None] - t.nvfp4_mag), axis=-1)
    sign = (x < 0).astype(jnp.uint8)
    return (sign * jnp.uint8(8) + idx.astype(jnp.uint8)).astype(jnp.uint8)


def nvfp4_decode_jnp(codes, t: Tables):
    c = codes.astype(jnp.int32)
    mag = t.nvfp4_mag[c & 7]
    sign = jnp.where((c & 8) != 0, -1.0, 1.0).astype(jnp.float32)
    return sign * mag


def ternary_encode_jnp(x):
    return jnp.where(x > 0.5, jnp.uint8(1), jnp.where(x < -0.5, jnp.uint8(2), jnp.uint8(0)))


def ternary_decode_jnp(codes):
    c = codes.astype(jnp.int32)
    return jnp.where(c == 1, 1.0, jnp.where(c == 2, -1.0, 0.0)).astype(jnp.float32)


def dequant_any_jnp(codes, scales, tags, t: Tables):
    """Tag-dispatched dequantization.

    codes: (..., D) u8; scales: (..., D/g) f32; tags: broadcastable to the
    leading axes of codes (one tag per cache slot).
    """
    g = F.GROUP_SIZE
    sc = jnp.repeat(scales, g, axis=-1)
    fp8 = e4m3_decode_jnp(codes, t) * sc
    nv4 = nvfp4_decode_jnp(codes, t) * sc
    ter = ternary_decode_jnp(codes) * sc
    tt = tags.astype(jnp.int32)
    while tt.ndim < codes.ndim:
        tt = tt[..., None]
    return jnp.where(tt == F.TAG_FP8, fp8, jnp.where(tt == F.TAG_NVFP4, nv4, ter))


def quant_groups_jnp(x, tag: int, t: Tables):
    """jnp mirror of ref.quant_groups_ref (used inside the Pallas kernel)."""
    g = F.GROUP_SIZE
    lead = x.shape[:-1]
    d = x.shape[-1]
    gs = x.reshape(*lead, d // g, g)
    if tag == F.TAG_FP8:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = e4m3_snap_jnp(amax / F.FP8_MAX, t)
        scale = jnp.where(scale <= 0, 1.0, scale)
        codes = e4m3_encode_jnp(x / scale, t)
        scales = jnp.broadcast_to(scale, (*lead, d // g))
        return codes, scales.astype(jnp.float32)
    if tag == F.TAG_NVFP4:
        amax = jnp.max(jnp.abs(gs), axis=-1, keepdims=True)
        scale = e4m3_snap_jnp(amax / F.NVFP4_MAX, t)
        scale = jnp.where(scale <= 0, 1.0, scale)
        codes = nvfp4_encode_jnp(gs / scale, t)
        return codes.reshape(*lead, d), scale[..., 0].astype(jnp.float32)
    if tag == F.TAG_TERNARY:
        amean = jnp.mean(jnp.abs(gs), axis=-1, keepdims=True)
        scale = e4m3_snap_jnp(amean, t)
        scale = jnp.where(scale <= 0, 1.0, scale)
        codes = ternary_encode_jnp(gs / scale)
        return codes.reshape(*lead, d), scale[..., 0].astype(jnp.float32)
    raise ValueError(f"unknown tag {tag}")


# --------------------------------------------------------------------------
# Pallas kernel
# --------------------------------------------------------------------------

def _quant_kernel(x_ref, t0, t1, t2, t3, codes_ref, scales_ref, *, tag: int):
    t = Tables(t0[...], t1[...], t2[...], t3[...])
    codes, scales = quant_groups_jnp(x_ref[...], tag, t)
    codes_ref[...] = codes
    scales_ref[...] = scales


def _table_specs():
    return [
        pl.BlockSpec((256,), lambda i: (0,)),
        pl.BlockSpec((F.E4M3_POS_VALUES.shape[0],), lambda i: (0,)),
        pl.BlockSpec((F.E4M3_POS_CODES.shape[0],), lambda i: (0,)),
        pl.BlockSpec((8,), lambda i: (0,)),
    ]


@functools.partial(jax.jit, static_argnames=("tag", "block_rows"))
def group_quantize(x, *, tag: int, block_rows: int = 8):
    """Pallas group quantization over rows of `x` (N, D).

    Returns (codes u8 (N, D), scales f32 (N, D/g)).  The grid tiles rows so a
    row-block's activations stay VMEM-resident while its group statistics,
    scale snap, and code search run fused in one pass.
    """
    n, d = x.shape
    g = F.GROUP_SIZE
    assert d % g == 0 and n % block_rows == 0, (n, d)
    grid = (n // block_rows,)
    t = tables_jnp()
    return pl.pallas_call(
        functools.partial(_quant_kernel, tag=tag),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))] + _table_specs(),
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d // g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.uint8),
            jax.ShapeDtypeStruct((n, d // g), jnp.float32),
        ],
        interpret=True,
    )(x, *t)
