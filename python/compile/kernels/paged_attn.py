"""L1 Pallas kernels: fused dequantization + paged decode attention.

The paper's compute hot-spot (§5, §6.1 "System Optimizations"): decode-step
attention over a slot-structured (paged) KV cache whose entries are stored
in mixed precision (FP8 / NVFP4 / ternary per thought type), with
dequantization *fused* into the attention kernel ("we fuse dequantization
with matrix multiplication to reduce overhead", §6.1).

Hardware adaptation (DESIGN §3): the CUDA/Triton threadblock schedule of the
paper becomes a Pallas grid over physical KV blocks; each grid step stages
one `[BS, Hkv, D]` code tile (+ scales/tags/mask) from HBM into VMEM via
BlockSpec and accumulates a streaming (flash) softmax.  Slot order is
irrelevant — attention is permutation invariant (paper Theorem 1) — which is
exactly what lets Continuous Thinking reuse evicted slots in place.

Everything is lowered `interpret=True` (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import formats as F
from compile.kernels import quant as Q

NEG = -1e30


def _flash_block(q, k, v, mask, i, scores_ref, acc_ref, m_ref, l_ref):
    """One streaming-softmax accumulation step.

    q: (H, D); k, v: (BS, H, D) already expanded to query heads;
    mask: (BS,).  Writes raw masked scores for this block and updates the
    running (max, denom, acc) carried in the output refs.
    """
    h, d = q.shape

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    # Contractions are written as broadcast-multiply-reduce: with tiny H/D
    # they lower to plain elementwise+reduce HLO that XLA fuses into the
    # surrounding kernel body (verified equivalent to einsum vs ref.py).
    s = jnp.sum(k * q[None, :, :], axis=-1).T / math.sqrt(d)  # (H, BS)
    s = jnp.where(mask[None, :] > 0, s, NEG)
    scores_ref[...] = s

    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None]) * (mask[None, :] > 0)
    pv = jnp.sum(p.T[:, :, None] * v, axis=0)  # (H, D)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    l_ref[...] = l_ref[...] * alpha[:, None] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new[:, None]


def _fused_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, tag_ref, mask_ref,
                  t0, t1, t2, t3,
                  scores_ref, acc_ref, m_ref, l_ref, *, rep: int):
    i = pl.program_id(0)
    q = q_ref[...]
    tags = tag_ref[...]
    t = Q.Tables(t0[...], t1[...], t2[...], t3[...])
    k = Q.dequant_any_jnp(kc_ref[...], ks_ref[...], tags[:, None], t)
    v = Q.dequant_any_jnp(vc_ref[...], vs_ref[...], tags[:, None], t)
    k = jnp.repeat(k, rep, axis=1)  # (BS, H, D)
    v = jnp.repeat(v, rep, axis=1)
    _flash_block(q, k, v, mask_ref[...], i, scores_ref, acc_ref, m_ref, l_ref)


def _fp32_kernel(q_ref, k_ref, v_ref, mask_ref,
                 scores_ref, acc_ref, m_ref, l_ref, *, rep: int):
    i = pl.program_id(0)
    q = q_ref[...]
    k = jnp.repeat(k_ref[...], rep, axis=1)
    v = jnp.repeat(v_ref[...], rep, axis=1)
    _flash_block(q, k, v, mask_ref[...], i, scores_ref, acc_ref, m_ref, l_ref)



def _pick_block(c: int, block: int) -> int:
    """Largest tile size <= `block` that divides the region length."""
    b = min(block, c)
    while c % b != 0:
        b -= 1
    return b

def _common_specs(h, d, g, hkv, block):
    q_spec = pl.BlockSpec((h, d), lambda i: (0, 0))
    out_specs = [
        pl.BlockSpec((h, block), lambda i: (0, i)),  # scores
        pl.BlockSpec((h, d), lambda i: (0, 0)),      # acc
        pl.BlockSpec((h, 1), lambda i: (0, 0)),      # m
        pl.BlockSpec((h, 1), lambda i: (0, 0)),      # l
    ]
    return q_spec, out_specs


def _out_shapes(h, d, c):
    return [
        jax.ShapeDtypeStruct((h, c), jnp.float32),
        jax.ShapeDtypeStruct((h, d), jnp.float32),
        jax.ShapeDtypeStruct((h, 1), jnp.float32),
        jax.ShapeDtypeStruct((h, 1), jnp.float32),
    ]


def fused_paged_attention_parts(q, k_codes, k_scales, v_codes, v_scales, tags,
                                mask, *, block: int = 128):
    """Flash accumulation over the quantized region only.

    Returns (scores (H,C) raw, acc (H,D), m (H,1), l (H,1)) — merged with the
    full-precision ring buffer by `merge_buffer`.
    """
    h, d = q.shape
    c, hkv, _ = k_codes.shape
    g = F.GROUP_SIZE
    rep = h // hkv
    block = _pick_block(c, block)
    q_spec, out_specs = _common_specs(h, d, g, hkv, block)
    t = Q.tables_jnp()
    return pl.pallas_call(
        functools.partial(_fused_kernel, rep=rep),
        grid=(c // block,),
        in_specs=[
            q_spec,
            pl.BlockSpec((block, hkv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, hkv, d // g), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, hkv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, hkv, d // g), lambda i: (i, 0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ] + [
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((t.pos_vals.shape[0],), lambda i: (0,)),
            pl.BlockSpec((t.pos_codes.shape[0],), lambda i: (0,)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=_out_shapes(h, d, c),
        interpret=True,
    )(q, k_codes, k_scales, v_codes, v_scales, tags, mask, *t)


def paged_attention_fp32_parts(q, k, v, mask, *, block: int = 128):
    """Flash accumulation over an f32 cache region (FullKV / eviction-only)."""
    h, d = q.shape
    c, hkv, _ = k.shape
    g = F.GROUP_SIZE
    rep = h // hkv
    block = _pick_block(c, block)
    q_spec, out_specs = _common_specs(h, d, g, hkv, block)
    return pl.pallas_call(
        functools.partial(_fp32_kernel, rep=rep),
        grid=(c // block,),
        in_specs=[
            q_spec,
            pl.BlockSpec((block, hkv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, hkv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=out_specs,
        out_shape=_out_shapes(h, d, c),
        interpret=True,
    )(q, k, v, mask)


def merge_buffer(parts, q, buf_k, buf_v, buf_mask):
    """Merge the flash partials with the full-precision ring buffer region.

    This is the standard flash-merge epilogue: the buffer is tiny (B_buf =
    group size g per paper §4.2) so it runs as plain fused HLO in the same
    jitted module.  Returns (out (H,D), probs (H, C+BUF)).
    """
    scores_q, acc, m, l = parts
    h, d = q.shape
    rep = h // buf_k.shape[1]
    kb = jnp.repeat(buf_k, rep, axis=1)  # (BUF, H, D)
    vb = jnp.repeat(buf_v, rep, axis=1)
    sb = jnp.sum(kb * q[None, :, :], axis=-1).T / math.sqrt(d)  # (H, BUF)
    sb = jnp.where(buf_mask[None, :] > 0, sb, NEG)

    m_tot = jnp.maximum(m[:, 0], jnp.max(sb, axis=1))
    alpha = jnp.exp(m[:, 0] - m_tot)
    pb = jnp.exp(sb - m_tot[:, None]) * (buf_mask[None, :] > 0)
    acc_tot = acc * alpha[:, None] + jnp.sum(pb.T[:, :, None] * vb, axis=0)
    l_tot = l[:, 0] * alpha + jnp.sum(pb, axis=1)
    out = acc_tot / jnp.where(l_tot > 0, l_tot, 1.0)[:, None]

    # Joint softmax row for the thought classifier / baselines.
    s_all = jnp.concatenate([scores_q, sb], axis=1)
    m_all = jnp.max(s_all, axis=1, keepdims=True)
    e = jnp.exp(s_all - m_all)
    e = jnp.where(s_all <= NEG / 2, 0.0, e)
    z = jnp.sum(e, axis=1, keepdims=True)
    probs = e / jnp.where(z > 0, z, 1.0)
    return out, probs


def fused_paged_attention(q, k_codes, k_scales, v_codes, v_scales, tags, mask,
                          buf_k, buf_v, buf_mask, *, block: int = 128):
    """Full fused path: quantized paged region + fp ring buffer."""
    parts = fused_paged_attention_parts(
        q, k_codes, k_scales, v_codes, v_scales, tags, mask, block=block)
    return merge_buffer(parts, q, buf_k, buf_v, buf_mask)


def gather_block_rows(arena, table):
    """Per-layer block-table gather over a shared physical cache arena.

    The multi-request decode artifacts (ThinKV §kernel: PagedAttention
    extended with per-request block tables) stack B requests over ONE
    physical arena: `arena` is `(L, A, ...)` with every request's slots —
    and any shared prompt prefix exactly once — laid out along A, and
    `table` is `(L, C)` int32 arena-row indices for one request. Rows a
    request does not own are simply never indexed, which is what lets N
    requests alias one resident copy of a shared system prompt.

    Returns `(L, C, ...)` — the request-local cache view the single-request
    attention kernel consumes unchanged (slot order is arbitrary, Theorem 1).
    """
    return jax.vmap(lambda rows, idx: jnp.take(rows, idx, axis=0))(arena, table)


def paged_attention_fp32(q, k, v, mask, buf_k, buf_v, buf_mask, *, block: int = 128):
    """FullKV / eviction-baseline path: f32 paged region + fp ring buffer."""
    parts = paged_attention_fp32_parts(q, k, v, mask, block=block)
    return merge_buffer(parts, q, buf_k, buf_v, buf_mask)
