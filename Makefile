# ThinKV build/verify entry points.
#
#   make artifacts  — AOT-lower the JAX/Pallas model to HLO text (once)
#   make tier1      — the repo's tier-1 verification command
#   make doc        — rustdoc with warnings denied (the docs gate)
#   make check      — fmt + clippy + doc + tier1 (what CI runs)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: check fmt clippy doc tier1 test artifacts clean

check: fmt clippy doc tier1

fmt:
	$(CARGO) fmt --check

# Lint allowlist: `too_many_arguments` is endemic to the engine FFI
# surface (cache slabs are passed as flat tensors by design).
clippy:
	$(CARGO) clippy --all-targets -- -D warnings -A clippy::too_many_arguments

# Docs gate: the rustdoc surface (crate/module docs, intra-doc links,
# doc examples) must build warning-free so it cannot rot.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

tier1:
	$(CARGO) build --release && $(CARGO) test -q

test: tier1

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf artifacts
