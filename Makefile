# ThinKV build/verify entry points.
#
#   make artifacts   — AOT-lower the JAX/Pallas model to HLO text (once)
#   make tier1       — the repo's tier-1 verification command
#   make doc         — rustdoc with warnings denied (the docs gate)
#   make doc-links   — README/ARCHITECTURE cross-references must resolve
#   make bench-smoke — one-iteration bench_scheduler run (bench rot gate)
#   make xtask-lint  — SchedSnapshot counter-map drift lint (+ its tests)
#   make loom        — exhaustive-interleaving models of the lock dances
#   make check       — fmt + clippy + doc + doc-links + xtask-lint +
#                      tier1 + loom (what CI runs)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: check fmt clippy doc doc-links xtask-lint loom tier1 test bench-smoke artifacts clean

check: fmt clippy doc doc-links xtask-lint tier1 loom

fmt:
	$(CARGO) fmt --check

# Lint allowlist: `too_many_arguments` is endemic to the engine FFI
# surface (cache slabs are passed as flat tensors by design). On top of
# the default set (denied), a curated slice of pedantic lints that have
# caught real bugs here: by-value args that force clones, lossless
# `as` casts that hide width changes, and clones of values never used
# again.
clippy:
	$(CARGO) clippy --all-targets -- -D warnings -A clippy::too_many_arguments \
	  -W clippy::needless_pass_by_value -W clippy::cast_lossless \
	  -W clippy::redundant_clone

# Counter-map drift lint: the SchedSnapshot JSON keys, the
# ARCHITECTURE.md counter map, and the README stats ledger must agree
# in both directions. The xtask unit tests prove the detector fires on
# seeded drift.
xtask-lint:
	$(CARGO) run -p xtask --quiet -- lint
	$(CARGO) test -p xtask -q

# Deterministic interleaving models (syncx::model) of the three
# cross-lock dances; each ships a seeded-bug variant proving the model
# catches the race it guards. See ARCHITECTURE.md "Invariants and
# analysis".
loom:
	$(CARGO) test --test loom_models -q

# Docs gate: the rustdoc surface (crate/module docs, intra-doc links,
# doc examples) must build warning-free so it cannot rot.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Doc-link check: relative markdown links in the top-level docs must
# resolve (README <-> docs/ARCHITECTURE.md cross-references).
doc-links:
	sh scripts/check_doc_links.sh

tier1:
	$(CARGO) build --release && $(CARGO) test -q

test: tier1

# Bench rot gate: one pass of the scheduler bench (cost-model parts +
# the artifact-free shared-prefix and arrival-burst sweeps; the
# real-coordinator part stays off so no artifacts are needed). Asserts
# inside the bench double as acceptance checks (throughput must rise
# with decode batch size, fused step must beat N single steps, sharing
# must multiply admission, chunked prefill must keep running-session
# TPOT strictly below the whole-prompt baseline, the goodput policy
# must strictly beat FIFO on SLO attainment over a pinned-seed arrival
# trace, the skewed 2-replica fleet must live-migrate and not lose
# goodput to a singleton), and the greps pin the prefix-hit,
# interleaved-prefill, fused-execute, prefix-alias, goodput, migration,
# and lane-width counters nonzero so none of those paths can silently
# regress (always-miss sharing / whole-prompt prefill / per-member
# decode executes / attach-by-memcpy / never-scoring SLO ledger /
# never-migrating replica tier).
# (No pipe here: a pipe would discard the bench's own exit status under
# POSIX sh; capture to a file so both the bench result and the grep gate
# propagate.)
bench-smoke:
	THINKV_BENCH_REAL=0 $(CARGO) bench --bench bench_scheduler > bench_smoke.out 2>&1; \
	status=$$?; cat bench_smoke.out; \
	[ $$status -eq 0 ] && grep -Eq "^prefix_hits=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^prefill_interleaved=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^fused_executes=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^prefix_alias_hits=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^goodput=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^policy_divergence=0$$" bench_smoke.out \
	  && grep -Eq "^migrations=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -Eq "^lane_width=[1-9][0-9]*$$" bench_smoke.out \
	  && grep -q "skipping real-coordinator" bench_smoke.out; \
	status=$$?; rm -f bench_smoke.out; exit $$status

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf artifacts
